//! Winograd-based convolution, F(2×2, 3×3) — the `Wino.cpu`/`Wino.gpu`
//! baseline (paper §2.2, §4; Lavin 2015).
//!
//! Only applicable when `k_h = k_w = 3` and `s_h = s_w = 1` (the paper
//! benchmarks it on cv6–cv12 only, for exactly this reason). Each 2×2
//! output tile is computed from a 4×4 input tile with 16 multiplies
//! instead of 36:
//!
//! ```text
//!   Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A
//! ```
//!
//! Summed over input channels, the elementwise products become 16
//! independent GEMMs of shape `(k_c × i_c) × (i_c × P)` where
//! `P = i_n·⌈o_h/2⌉·⌈o_w/2⌉` — the paper's Appendix describes exactly
//! this "all tiles/channels in full parallel" decomposition, and its
//! memory cost: transformed-input V and product M are materialized in
//! full, which is why Fig. 4b/e show Winograd needing noticeably more
//! temporary memory than MEC.
//!
//! Plan/execute: the transformed filters U = G g Gᵀ are input-independent
//! — cuDNN-style, the plan computes them once and holds them as model
//! memory (like a prepacked weight), so the per-call workspace is V + M
//! and execute performs no filter transforms.

use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::gemm::{gemm_ex, KernelBackend, MatMut, MatRef};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::any::Any;
use std::sync::Arc;

pub struct Winograd;

/// The transformed filters U = G g Gᵀ (16 matrices of k_c×i_c) —
/// batch-independent, shared across a layer's per-batch-size plans.
pub struct WinogradPrepack {
    pub u: Vec<f32>,
}

impl KernelPrepack for WinogradPrepack {
    fn bytes(&self) -> usize {
        self.u.len() * 4
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

/// Tiles along one axis: 2-output tiles, ceil.
fn tiles(o: usize) -> usize {
    o.div_ceil(2)
}

/// Total tile count `P = i_n · ⌈o_h/2⌉ · ⌈o_w/2⌉`.
pub fn tile_count(shape: &ConvShape) -> usize {
    shape.input.n * tiles(shape.oh()) * tiles(shape.ow())
}

impl Convolution for Winograd {
    fn name(&self) -> &'static str {
        "winograd"
    }

    /// F(2×2,3×3) requires 3×3 kernels with unit stride (paper §4:
    /// "applicable only when k_h = k_w = 3").
    fn supports(&self, s: &ConvShape) -> bool {
        s.kernel.kh == 3 && s.kernel.kw == 3 && s.sh == 1 && s.sw == 1
    }

    /// U (16·k_c·i_c) + V (16·i_c·P) + M (16·k_c·P) floats — the total
    /// temporary memory beyond I/K/O, which is what the planner budgets
    /// against. A plan carves U out as plan-resident
    /// ([`ConvPlan::resident_bytes`]), so its per-call scratch layout is
    /// only V + M.
    fn workspace_elems(&self, s: &ConvShape) -> usize {
        let p = tile_count(s);
        let (ic, kc) = (s.kernel.ic, s.kernel.kc);
        16 * kc * ic + 16 * ic * p + 16 * kc * p
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert!(
            self.supports(shape),
            "winograd: unsupported geometry {}",
            shape.describe()
        );
        assert_eq!(kernel.shape(), shape.kernel);
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);
        // ---- plan-time: U[xy][o][i] = (G g Gᵀ)[xy] once ----
        let mut u = vec![0.0f32; 16 * kc * ic];
        kernel_transform(ctx, kernel, ic, kc, &mut u);
        Arc::new(WinogradPrepack { u })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        assert!(
            self.supports(shape),
            "winograd: unsupported geometry {}",
            shape.describe()
        );
        let prepack: Arc<WinogradPrepack> = downcast_prepack(prepack, "winograd");
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);
        assert_eq!(prepack.u.len(), 16 * kc * ic, "winograd: prepack shape mismatch");
        let p = tile_count(shape);
        let mut layout = WorkspaceLayout::new();
        layout.push("input-transform", 16 * ic * p);
        layout.push("products", 16 * kc * p);
        Box::new(WinogradPlan {
            ctx: ctx.clone(),
            shape: *shape,
            prepack,
            layout,
            backend: KernelBackend::active(),
        })
    }
}

/// Plan for fully-materialized F(2×2,3×3): transformed filters resident
/// (shared), V and M regions laid out.
pub struct WinogradPlan {
    ctx: ConvContext,
    shape: ConvShape,
    /// Transformed filters, 16 matrices of k_c×i_c ([xy][o][i]).
    prepack: Arc<WinogradPrepack>,
    layout: WorkspaceLayout,
    /// The micro-kernel backend the 16 point-wise GEMMs dispatch to,
    /// frozen at plan time (observability: engine report, benches).
    backend: KernelBackend,
}

impl ConvPlan for WinogradPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Winograd
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        Some(self.backend)
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl WinogradPlan {
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), s.input);
        let (ic, kc) = (s.kernel.ic, s.kernel.kc);
        let (oh, ow) = (s.oh(), s.ow());
        let (th, tw) = (tiles(oh), tiles(ow));
        let p = s.input.n * th * tw;

        let (v, m) = scratch[..16 * ic * p + 16 * kc * p].split_at_mut(16 * ic * p);

        // ---- 1. Input transform: V[xy][i][p] = (Bᵀ d B)[xy] ----
        input_transform(ctx, &s, input, th, tw, v);

        // ---- 2. 16 batched GEMMs: M[xy] = U[xy] (kc×ic) × V[xy] (ic×P) ----
        {
            let m_shared = SharedSlice::new(m);
            let u_ref: &[f32] = &self.prepack.u;
            let v_ref: &[f32] = v;
            // Outer loop over the 16 point-wise GEMMs; a nested gemm_ex
            // finds the pool busy and runs inline, so there is no
            // oversubscription at any budget (and when the outer loop is
            // below the grain cutoff, the inner GEMMs get the pool).
            ctx.par.parallel_for_macs(16, kc * ic * p, |xy| {
                let m_data = m_shared.slice();
                let a = MatRef::new(&u_ref[xy * kc * ic..(xy + 1) * kc * ic], kc, ic);
                let b = MatRef::new(&v_ref[xy * ic * p..(xy + 1) * ic * p], ic, p);
                let mut c = MatMut::new(&mut m_data[xy * kc * p..(xy + 1) * kc * p], kc, p);
                gemm_ex(a, b, &mut c, 1.0, 0.0, &ctx.par, ctx.blocks);
            });
        }

        // ---- 3. Output transform: Y = Aᵀ m A per (tile, kc), clipped ----
        output_transform(ctx, &s, m, th, tw, output);
    }
}

/// G g Gᵀ for every (o, i); U laid out as 16 matrices of kc×ic. Shared by
/// the full and chunked variants (plan-time only).
pub(super) fn kernel_transform(
    ctx: &ConvContext,
    kernel: &Kernel,
    ic: usize,
    kc: usize,
    u: &mut [f32],
) {
    let u_shared = SharedSlice::new(u);
    // Plan-time only; ~32 MACs + 16 stores per (o, i).
    ctx.par.parallel_for_macs(kc * ic, 48, |t| {
        let u_data = u_shared.slice();
        let o = t / ic;
        let i = t % ic;
        // g: 3x3 slice for (i, o).
        let mut g = [[0.0f32; 3]; 3];
        for (r, grow) in g.iter_mut().enumerate() {
            for (c, gval) in grow.iter_mut().enumerate() {
                *gval = kernel.at(r, c, i, o);
            }
        }
        // G (4x3): rows [1,0,0],[.5,.5,.5],[.5,-.5,.5],[0,0,1]
        // t1 = G·g (4x3)
        let mut t1 = [[0.0f32; 3]; 4];
        for c in 0..3 {
            t1[0][c] = g[0][c];
            t1[1][c] = 0.5 * (g[0][c] + g[1][c] + g[2][c]);
            t1[2][c] = 0.5 * (g[0][c] - g[1][c] + g[2][c]);
            t1[3][c] = g[2][c];
        }
        // ugg = t1·Gᵀ (4x4)
        for (r, row) in t1.iter().enumerate() {
            let out = [
                row[0],
                0.5 * (row[0] + row[1] + row[2]),
                0.5 * (row[0] - row[1] + row[2]),
                row[2],
            ];
            for (xy_c, &val) in out.iter().enumerate() {
                let xy = r * 4 + xy_c;
                u_data[xy * kc * ic + o * ic + i] = val;
            }
        }
    });
}

/// Bᵀ d B for every (tile, i); V laid out as 16 matrices of ic×P. Input
/// tiles read with zero padding at the bottom/right edges (odd o_h/o_w).
fn input_transform(
    ctx: &ConvContext,
    s: &ConvShape,
    input: &Tensor,
    th: usize,
    tw: usize,
    v: &mut [f32],
) {
    let ish = s.input;
    let ic = s.kernel.ic;
    let p = ish.n * th * tw;
    let v_shared = SharedSlice::new(v);
    let in_data = input.data();
    // Grain: ~16 loads + 16 stores + 32 adds per (tile, channel).
    ctx.par.parallel_for_bytes(p, ic * 160, |tile| {
        let v_data = v_shared.slice();
        let n = tile / (th * tw);
        let ty = (tile / tw) % th;
        let tx = tile % tw;
        let (y0, x0) = (2 * ty, 2 * tx);
        for i in 0..ic {
            // d: 4x4 input patch (zero beyond bounds).
            let mut d = [[0.0f32; 4]; 4];
            for (r, drow) in d.iter_mut().enumerate() {
                let y = y0 + r;
                if y >= ish.h {
                    continue;
                }
                for (c, dval) in drow.iter_mut().enumerate() {
                    let x = x0 + c;
                    if x < ish.w {
                        *dval = in_data[ish.index(n, y, x, i)];
                    }
                }
            }
            // t1 = Bᵀ·d where Bᵀ rows: [1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]
            let mut t1 = [[0.0f32; 4]; 4];
            for c in 0..4 {
                t1[0][c] = d[0][c] - d[2][c];
                t1[1][c] = d[1][c] + d[2][c];
                t1[2][c] = d[2][c] - d[1][c];
                t1[3][c] = d[1][c] - d[3][c];
            }
            // vt = t1·B (apply the same combination to columns).
            for (r, row) in t1.iter().enumerate() {
                let out = [
                    row[0] - row[2],
                    row[1] + row[2],
                    row[2] - row[1],
                    row[1] - row[3],
                ];
                for (c, &val) in out.iter().enumerate() {
                    let xy = r * 4 + c;
                    v_data[xy * ic * p + i * p + tile] = val;
                }
            }
        }
    });
}

/// Y = Aᵀ m A per (tile, o); writes 2×2 outputs with edge clipping.
fn output_transform(
    ctx: &ConvContext,
    s: &ConvShape,
    m: &[f32],
    th: usize,
    tw: usize,
    output: &mut Tensor,
) {
    let osh = s.output();
    let kc = s.kernel.kc;
    let p = s.input.n * th * tw;
    let out_shared = SharedSlice::new(output.data_mut());
    ctx.par.parallel_for_bytes(p, kc * 160, |tile| {
        let out_data = out_shared.slice();
        let n = tile / (th * tw);
        let ty = (tile / tw) % th;
        let tx = tile % tw;
        let (y0, x0) = (2 * ty, 2 * tx);
        for o in 0..kc {
            // mm: 4x4 gathered from the 16 GEMM outputs.
            let mut mm = [[0.0f32; 4]; 4];
            for (r, mrow) in mm.iter_mut().enumerate() {
                for (c, mval) in mrow.iter_mut().enumerate() {
                    let xy = r * 4 + c;
                    *mval = m[xy * kc * p + o * p + tile];
                }
            }
            // t1 = Aᵀ·mm, Aᵀ = [1,1,1,0],[0,1,-1,-1] (2x4)
            let mut t1 = [[0.0f32; 4]; 2];
            for c in 0..4 {
                t1[0][c] = mm[0][c] + mm[1][c] + mm[2][c];
                t1[1][c] = mm[1][c] - mm[2][c] - mm[3][c];
            }
            // y = t1·A (2x2)
            for (r, trow) in t1.iter().enumerate() {
                let y = y0 + r;
                if y >= osh.h {
                    continue;
                }
                let vals = [
                    trow[0] + trow[1] + trow[2],
                    trow[1] - trow[2] - trow[3],
                ];
                for (c, &val) in vals.iter().enumerate() {
                    let x = x0 + c;
                    if x < osh.w {
                        out_data[osh.index(n, y, x, o)] = val;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    fn check(n: usize, ih: usize, iw: usize, ic: usize, kc: usize, threads: usize, seed: u64) {
        let shape = ConvShape::new(
            Nhwc::new(n, ih, iw, ic),
            KernelShape::new(3, 3, ic, kc),
            1,
            1,
        );
        let mut rng = Rng::new(seed);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default().with_threads(threads);
        let mut want = Tensor::zeros(shape.output());
        let mut got = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        Winograd.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
        // Winograd loses a little precision (the 0.5 factors + gather),
        // tolerance slightly looser than the gemm-family algorithms.
        assert_allclose(got.data(), want.data(), 1e-3, &shape.describe());
    }

    #[test]
    fn matches_direct_even_output() {
        check(1, 6, 6, 1, 1, 1, 1);
        check(2, 10, 6, 3, 4, 1, 2);
    }

    #[test]
    fn matches_direct_odd_output_needs_clipping() {
        // o_h = o_w = 5 (odd): last tile row/col is half-valid.
        check(1, 7, 7, 1, 1, 1, 3);
        check(1, 9, 7, 2, 3, 1, 4);
    }

    #[test]
    fn matches_direct_threaded() {
        check(2, 12, 12, 4, 5, 4, 5);
    }

    #[test]
    fn supports_only_3x3_stride1() {
        let ok = ConvShape::new(Nhwc::new(1, 8, 8, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let bad_k = ConvShape::new(Nhwc::new(1, 8, 8, 1), KernelShape::new(5, 5, 1, 1), 1, 1);
        let bad_s = ConvShape::new(Nhwc::new(1, 8, 8, 1), KernelShape::new(3, 3, 1, 1), 2, 2);
        assert!(Winograd.supports(&ok));
        assert!(!Winograd.supports(&bad_k));
        assert!(!Winograd.supports(&bad_s));
    }

    #[test]
    fn workspace_formula() {
        let s = ConvShape::new(
            Nhwc::new(1, 7, 7, 8),
            KernelShape::new(3, 3, 8, 16),
            1,
            1,
        );
        let p = 3 * 3; // ⌈5/2⌉ × ⌈5/2⌉
        assert_eq!(tile_count(&s), p);
        // Analytic total: U + V + M (what the planner budgets).
        assert_eq!(
            Winograd.workspace_elems(&s),
            16 * 16 * 8 + 16 * 8 * p + 16 * 16 * p
        );
        // The plan carves U out as resident memory; per-call scratch is
        // V + M, and resident + scratch covers the analytic total.
        let kernel = Kernel::zeros(s.kernel);
        let plan = Winograd.plan(&ConvContext::default(), &s, &kernel);
        assert_eq!(plan.workspace_elems(), 16 * 8 * p + 16 * 16 * p);
        assert_eq!(plan.resident_bytes(), 16 * 16 * 8 * 4);
        assert_eq!(
            plan.resident_bytes() + plan.workspace_bytes(),
            Winograd.workspace_bytes(&s)
        );
        // Winograd overhead exceeds MEC's on this shape (Fig. 4b story).
        assert!(Winograd.workspace_elems(&s) > s.mec_lowered_elems());
    }

    #[test]
    fn identity_kernel_center() {
        // Kernel = delta at center: winograd must reproduce the crop.
        let shape = ConvShape::new(Nhwc::new(1, 6, 6, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let input = Tensor::from_fn(shape.input, |_, h, w, _| (h * 6 + w) as f32);
        let kernel = Kernel::from_fn(shape.kernel, |h, w, _, _| {
            if h == 1 && w == 1 {
                1.0
            } else {
                0.0
            }
        });
        let mut out = Tensor::zeros(shape.output());
        Winograd.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut out,
        );
        for y in 0..4 {
            for x in 0..4 {
                assert!(
                    (out.at(0, y, x, 0) - input.at(0, y + 1, x + 1, 0)).abs() < 1e-4,
                    "y={y} x={x}"
                );
            }
        }
    }
}
