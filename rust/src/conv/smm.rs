//! SMM-Conv-style convolution: scalar-matrix accumulation with **zero
//! packing** and zero workspace, streaming over kernel positions.
//!
//! For each kernel position (u, v) and input channel i, the input
//! pixels an output row reads form a strided scalar sequence, and the
//! kernel holds one contiguous `k_c`-vector `K[u, v, i, :]`. The
//! product is a scalar × row-vector multiply accumulated into the
//! output row — a rank-1 update streamed over `k_h·k_w·i_c` positions
//! with no lowering, no im2col copy, and no GEMM-panel packing at all
//! (the "SMM" in SMM-Conv: scalar-matrix multiplication).
//!
//! Relative to `direct` (the same MACs, per-pixel loop order) the
//! kernel-position-outer order keeps one `i_c·k_c` kernel block hot
//! across the whole output row, and the innermost `k_c` loop
//! autovectorizes over contiguous memory on both operands. Relative to
//! the GEMM family it trades micro-kernel register blocking for zero
//! memory traffic beyond I/K/O — the cost model prices it between
//! `direct` and the packed lowerings, which is exactly where it lands.
//!
//! Per output element the accumulation order over (u, v, i) is
//! identical to `direct`'s, so the two produce bitwise-equal f32
//! results — handy for the differential oracle's tolerance table (0 for
//! both). f32-only: like `direct`, accumulation happens in the f32
//! output with no i16 partial-sum path.

use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::any::Any;
use std::sync::Arc;

pub struct SmmConv;

/// SMM's "prepack" is an owned kernel copy (self-contained plans, like
/// direct's) — zero packing is the algorithm's defining property.
pub struct SmmPrepack {
    pub kernel: Kernel,
}

impl KernelPrepack for SmmPrepack {
    fn bytes(&self) -> usize {
        self.kernel.bytes()
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

impl Convolution for SmmConv {
    fn name(&self) -> &'static str {
        "smm"
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    fn workspace_elems(&self, _shape: &ConvShape) -> usize {
        0 // zero packing, zero lowering — nothing beyond I/K/O
    }

    fn prepack(
        &self,
        _ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert_eq!(kernel.shape(), shape.kernel);
        Arc::new(SmmPrepack {
            kernel: kernel.clone(),
        })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let prepack: Arc<SmmPrepack> = downcast_prepack(prepack, "smm");
        assert_eq!(prepack.kernel.shape(), shape.kernel);
        Box::new(SmmPlan {
            ctx: ctx.clone(),
            shape: *shape,
            prepack,
            layout: WorkspaceLayout::new(),
        })
    }
}

/// Plan for SMM-Conv: shared kernel copy, empty layout.
pub struct SmmPlan {
    ctx: ConvContext,
    shape: ConvShape,
    prepack: Arc<SmmPrepack>,
    layout: WorkspaceLayout,
}

impl ConvPlan for SmmPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::SmmConv
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn execute_in(&self, input: &Tensor, _scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        _scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, output);
    }
}

impl SmmPlan {
    fn execute_with(&self, ctx: &ConvContext, input: &Tensor, output: &mut Tensor) {
        let s = self.shape;
        let k = s.kernel;
        let (oh, ow) = (s.oh(), s.ow());
        let ish = s.input;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), ish);

        let in_data = input.data();
        let k_data = self.prepack.kernel.data();
        let out = SharedSlice::new(output.data_mut());

        // Parallelize over (n, o_h): disjoint output rows, fixed
        // partitioning — bitwise identical at any thread count.
        let row_macs = ow * k.kh * k.kw * k.ic * k.kc;
        ctx.par.parallel_for_macs(ish.n * oh, row_macs, |r| {
            let (n, y) = (r / oh, r % oh);
            let out_data: &mut [f32] = out.slice();
            let row = &mut out_data[r * ow * k.kc..(r + 1) * ow * k.kc];
            row.fill(0.0);
            // Stream kernel positions: the i_c×k_c block for (u, v)
            // stays hot while the whole output row accumulates its
            // rank-1 updates. Per output element the (u, v, i) term
            // order matches direct's loop nest exactly (bitwise-equal
            // results).
            for u in 0..k.kh {
                for v in 0..k.kw {
                    let in_row = &in_data[ish.index(n, y * s.sh + u, v, 0)..];
                    let k_blk = &k_data[k.index(u, v, 0, 0)..k.index(u, v, 0, 0) + k.ic * k.kc];
                    for x in 0..ow {
                        let px = &in_row[x * s.sw * ish.c..x * s.sw * ish.c + k.ic];
                        let acc = &mut row[x * k.kc..(x + 1) * k.kc];
                        for (i, &sc) in px.iter().enumerate() {
                            // Scalar × kernel-row-vector, both contiguous.
                            let k_row = &k_blk[i * k.kc..(i + 1) * k.kc];
                            for (a, &kv) in acc.iter_mut().zip(k_row) {
                                *a += sc * kv;
                            }
                        }
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::Rng;

    #[test]
    fn zero_workspace_and_no_packing() {
        let shape = ConvShape::new(Nhwc::new(1, 8, 8, 3), KernelShape::new(3, 3, 3, 8), 1, 1);
        assert_eq!(Convolution::workspace_elems(&SmmConv, &shape), 0);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = SmmConv.plan(&ConvContext::default(), &shape, &kernel);
        assert_eq!(plan.workspace_elems(), 0);
        assert!(plan.layout().regions().is_empty());
        // Resident = the kernel copy, byte for byte: nothing was packed.
        assert_eq!(plan.resident_bytes(), shape.kernel.len() * 4);
        assert!(plan.kernel_backend().is_none());
    }

    #[test]
    fn bitwise_equals_direct() {
        // Same per-element (u, v, i) accumulation order as direct's loop
        // nest ⇒ exactly equal outputs, not just allclose.
        let mut rng = Rng::new(51);
        for (n, ih, iw, ic, kh, kw, kc, sh, sw) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1),
            (2, 9, 8, 3, 3, 2, 4, 2, 1),
            (1, 12, 12, 2, 5, 5, 3, 2, 2),
            (3, 6, 6, 4, 1, 1, 8, 1, 1),
            (1, 11, 5, 2, 4, 3, 2, 3, 2),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let ctx = ConvContext::default().with_threads(2);
            let mut want = Tensor::zeros(shape.output());
            let mut got = Tensor::zeros(shape.output());
            let mut ws = Workspace::new();
            Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
            SmmConv.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
            assert_eq!(want, got, "{}", shape.describe());
        }
    }
}
