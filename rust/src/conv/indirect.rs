//! Indirect convolution (Dukhan, "The Indirect Convolution Algorithm")
//! — im2col's GEMM without im2col's lowered matrix.
//!
//! The plan builds an **indirection buffer**: one input offset per
//! `(o_h, k_h, k_w)` triple, pointing at the `i_c`-channel input pixel
//! that output row `y`'s receptive field reads at kernel position
//! `(u, v)` when `x = 0` (the `x` dimension is a fixed `+x·s_w·i_c`
//! displacement, and the batch dimension a fixed sample stride, so
//! neither needs its own entries). That is `O(k_h·k_w·o_h)` pointer
//! memory — independent of batch, width, and of Eq. 2's lowering size.
//!
//! Execute gathers one output row's receptive field at a time through
//! the offset table into a small strip (at most [`GATHER_LANES`] strips
//! ride in the arena, one per parallel task) and runs the same prepacked
//! kernel GEMM as im2col over it. Workspace is `lanes·o_w·k_h·k_w·i_c`
//! — versus im2col's `i_n·o_h·o_w·k_h·k_w·i_c` — while keeping im2col's
//! arithmetic intensity per row. Under q16 the gather quantizes in the
//! same pass (exactly like im2col's quantize-while-lowering), halving
//! the strip bytes.

use super::{
    downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack, PackedKernel,
};
use crate::gemm::{
    gemm_prepacked, gemm_prepacked_i16, split_ranges, KernelBackend, MatMut, MatRef, MatRefI16,
    Q16Epilogue,
};
use crate::memory::WorkspaceLayout;
use crate::tensor::quant::{f32_as_i16_mut, i16_slots, Precision, QParams};
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::sync::Arc;

/// Upper bound on concurrent gather strips (and thus on the tasks the
/// row loop splits into). Fixed at plan time — not derived from the
/// context's thread budget — so the workspace layout, and the
/// task-to-row partitioning that makes results bitwise identical at any
/// thread count, never change under a session thread cap.
pub const GATHER_LANES: usize = 8;

pub struct IndirectConv;

/// Strips (= parallel tasks) for a geometry: one per output row up to
/// the cap.
fn lanes(shape: &ConvShape) -> usize {
    GATHER_LANES.min(shape.input.n * shape.oh()).max(1)
}

/// Elements of one gather strip: a full lowered row block for one
/// output row (`o_w` GEMM rows of `k_h·k_w·i_c`).
fn strip_elems(shape: &ConvShape) -> usize {
    let k = shape.kernel;
    shape.ow() * k.kh * k.kw * k.ic
}

/// The indirection buffer: `offsets[(y·k_h + u)·k_w + v]` is the
/// sample-relative element offset of input pixel `(y·s_h + u, v)` —
/// output row `y`'s read at kernel position `(u, v)`, output column 0.
fn offset_table(shape: &ConvShape) -> Vec<usize> {
    let k = shape.kernel;
    let ish = shape.input;
    let mut offsets = Vec::with_capacity(shape.oh() * k.kh * k.kw);
    for y in 0..shape.oh() {
        for u in 0..k.kh {
            for v in 0..k.kw {
                offsets.push(((y * shape.sh + u) * ish.w + v) * ish.c);
            }
        }
    }
    offsets
}

impl Convolution for IndirectConv {
    fn name(&self) -> &'static str {
        "indirect"
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    /// `lanes · o_w · k_h·k_w·i_c` floats — one lowered row block per
    /// concurrent task, constant in `i_n·o_h` once past the lane cap
    /// (≤ im2col's Eq. 2 by construction, equal only when the whole
    /// image has ≤ [`GATHER_LANES`] output rows).
    fn workspace_elems(&self, shape: &ConvShape) -> usize {
        lanes(shape) * strip_elems(shape)
    }

    /// q16 gathers into i16 lanes: half the strip bytes, like im2col's
    /// halved lowered matrix.
    fn workspace_bytes_prec(&self, shape: &ConvShape, precision: Precision) -> usize {
        match precision {
            Precision::F32 => self.workspace_bytes(shape),
            Precision::Q16 => i16_slots(self.workspace_elems(shape)) * 4,
        }
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        // Same GEMM B-operand as im2col (the kernel matrix is identical);
        // the indirection buffer is geometry-, not kernel-side, and lives
        // in the plan so batch-size sharing stays exact.
        Arc::new(PackedKernel::pack(ctx, shape, kernel))
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let packed_k: Arc<PackedKernel> = downcast_prepack(prepack, "indirect");
        let mut layout = WorkspaceLayout::new();
        match &*packed_k {
            PackedKernel::F32(_) => {
                layout.push("gather", lanes(shape) * strip_elems(shape));
            }
            PackedKernel::Q16 { .. } => {
                layout.push_i16("gather", lanes(shape) * strip_elems(shape));
            }
        }
        Box::new(IndirectPlan {
            ctx: ctx.clone(),
            shape: *shape,
            offsets: offset_table(shape),
            packed_k,
            layout,
        })
    }
}

/// Plan for indirect convolution: the shared prepacked kernel matrix +
/// the plan-resident indirection buffer + per-lane gather strips.
pub struct IndirectPlan {
    ctx: ConvContext,
    shape: ConvShape,
    /// The indirection buffer (see [`offset_table`]): `o_h·k_h·k_w`
    /// entries, plan-resident — the pointer memory the algorithm trades
    /// for im2col's lowering.
    offsets: Vec<usize>,
    packed_k: Arc<PackedKernel>,
    layout: WorkspaceLayout,
}

impl ConvPlan for IndirectPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Indirect
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.packed_k.bytes() + self.offsets.len() * std::mem::size_of::<usize>()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.packed_k) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        Some(self.packed_k.backend())
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl IndirectPlan {
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        let k = s.kernel;
        let (oh, ow) = (s.oh(), s.ow());
        let ish = s.input;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), ish);
        let rows = ish.n * oh;
        let row_len = k.kh * k.kw * k.ic;
        let strip = strip_elems(&s);
        let nlanes = lanes(&s);
        let sample = ish.h * ish.w * ish.c;

        let in_data = input.data();
        let offsets = &self.offsets;
        let out = SharedSlice::new(output.data_mut());
        // Fixed task-per-lane partitioning (not per-thread): lane `t`
        // owns a contiguous range of (n, y) output rows and strip `t`,
        // so results are bitwise identical at any thread count.
        let ranges = split_ranges(rows, nlanes);
        let lane_macs = rows.div_ceil(nlanes) * ow * row_len * k.kc;

        match &*self.packed_k {
            PackedKernel::F32(pk) => {
                let gp = SharedSlice::new(&mut scratch[..nlanes * strip]);
                ctx.par.parallel_for_macs(ranges.len(), lane_macs, |t| {
                    let (r0, r1) = ranges[t];
                    let g: &mut [f32] = gp.slice();
                    let lane = &mut g[t * strip..(t + 1) * strip];
                    let out_data: &mut [f32] = out.slice();
                    for r in r0..r1 {
                        let (n, y) = (r / oh, r % oh);
                        let base = n * sample;
                        let otab = &offsets[y * k.kh * k.kw..(y + 1) * k.kh * k.kw];
                        for x in 0..ow {
                            let dst = &mut lane[x * row_len..(x + 1) * row_len];
                            let dx = x * s.sw * ish.c;
                            for (j, &off) in otab.iter().enumerate() {
                                let src = base + off + dx;
                                dst[j * k.ic..(j + 1) * k.ic]
                                    .copy_from_slice(&in_data[src..src + k.ic]);
                            }
                        }
                        let a = MatRef::new(lane, ow, row_len);
                        let c_rows = &mut out_data[r * ow * k.kc..(r + 1) * ow * k.kc];
                        let mut c = MatMut::new(c_rows, ow, k.kc);
                        gemm_prepacked(a, pk, &mut c);
                    }
                });
            }
            PackedKernel::Q16 { packed, col_scales } => {
                let qa = ctx
                    .act_qparams
                    .unwrap_or_else(|| QParams::from_slice(input.data()));
                let ep = Q16Epilogue {
                    global: qa.scale * 32768.0,
                    per_col: Some(col_scales),
                };
                let slots = i16_slots(nlanes * strip);
                let g16 = &mut f32_as_i16_mut(&mut scratch[..slots])[..nlanes * strip];
                let gp = SharedSlice::new(g16);
                ctx.par.parallel_for_macs(ranges.len(), lane_macs, |t| {
                    let (r0, r1) = ranges[t];
                    let g: &mut [i16] = gp.slice();
                    let lane = &mut g[t * strip..(t + 1) * strip];
                    let out_data: &mut [f32] = out.slice();
                    for r in r0..r1 {
                        let (n, y) = (r / oh, r % oh);
                        let base = n * sample;
                        let otab = &offsets[y * k.kh * k.kw..(y + 1) * k.kh * k.kw];
                        for x in 0..ow {
                            let dst = &mut lane[x * row_len..(x + 1) * row_len];
                            let dx = x * s.sw * ish.c;
                            for (j, &off) in otab.iter().enumerate() {
                                let src = base + off + dx;
                                for (d, &v) in dst[j * k.ic..(j + 1) * k.ic]
                                    .iter_mut()
                                    .zip(&in_data[src..src + k.ic])
                                {
                                    *d = qa.quantize(v);
                                }
                            }
                        }
                        let a = MatRefI16::new(lane, ow, row_len);
                        let c_rows = &mut out_data[r * ow * k.kc..(r + 1) * ow * k.kc];
                        let mut c = MatMut::new(c_rows, ow, k.kc);
                        gemm_prepacked_i16(a, packed, &mut c, ep);
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    #[test]
    fn offset_table_is_oh_khkw_and_points_at_receptive_fields() {
        let shape = ConvShape::new(Nhwc::new(1, 9, 8, 3), KernelShape::new(3, 2, 3, 4), 2, 1);
        let t = offset_table(&shape);
        assert_eq!(t.len(), shape.oh() * 3 * 2);
        // Entry (y, u, v) points at input pixel (y·s_h + u, v) of an
        // 8-wide, 3-channel image.
        let (y, u, v) = (1usize, 2usize, 1usize);
        assert_eq!(t[(y * 3 + u) * 2 + v], ((y * 2 + u) * 8 + v) * 3);
    }

    #[test]
    fn workspace_is_lane_strips_not_eq2() {
        // cv1 geometry: the lowering would be 55·55 rows; indirect keeps 8.
        let shape = ConvShape::new(
            Nhwc::new(1, 227, 227, 3),
            KernelShape::new(11, 11, 3, 96),
            4,
            4,
        );
        assert_eq!(
            IndirectConv.workspace_elems(&shape),
            8 * 55 * 11 * 11 * 3
        );
        assert!(IndirectConv.workspace_elems(&shape) < shape.im2col_lowered_elems());
        // Tiny images degrade to im2col's footprint, never above it.
        let tiny = ConvShape::new(Nhwc::new(1, 4, 4, 2), KernelShape::new(3, 3, 2, 2), 1, 1);
        assert_eq!(
            IndirectConv.workspace_elems(&tiny),
            tiny.im2col_lowered_elems()
        );
    }

    #[test]
    fn matches_direct_on_random_geometries() {
        let mut rng = Rng::new(31);
        for (n, ih, iw, ic, kh, kw, kc, sh, sw) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1),
            (2, 9, 8, 3, 3, 2, 4, 2, 1),
            (1, 12, 12, 2, 5, 5, 3, 2, 2),
            (3, 6, 6, 4, 1, 1, 8, 1, 1),
            (1, 11, 5, 2, 4, 3, 2, 3, 2),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let ctx = ConvContext::default().with_threads(2);
            let mut want = Tensor::zeros(shape.output());
            let mut got = Tensor::zeros(shape.output());
            let mut ws = Workspace::new();
            Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
            IndirectConv.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
            assert_allclose(got.data(), want.data(), 1e-4, &shape.describe());
        }
    }

    #[test]
    fn q16_matches_direct_within_quantization_noise() {
        let shape = ConvShape::new(Nhwc::new(2, 10, 9, 3), KernelShape::new(3, 3, 3, 5), 1, 2);
        let mut rng = Rng::new(0x71);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut want = Tensor::zeros(shape.output());
        Direct.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut want,
        );
        for threads in [1usize, 3] {
            let ctx = ConvContext::default()
                .with_threads(threads)
                .with_precision(Precision::Q16);
            let plan = IndirectConv.plan(&ctx, &shape, &kernel);
            // Plain Vec scratch (not a tracked Arena): unit tests must not
            // perturb the global tracker the memory tests assert against.
            let mut scratch = vec![0.0f32; plan.workspace_elems()];
            let mut got = Tensor::zeros(shape.output());
            plan.execute_in(&input, &mut scratch, &mut got);
            assert_allclose(got.data(), want.data(), 1e-3, &format!("q16 t={threads}"));
        }
    }

    #[test]
    fn plan_reports_offset_table_in_resident_bytes() {
        let shape = ConvShape::new(Nhwc::new(1, 9, 9, 2), KernelShape::new(3, 3, 2, 4), 1, 1);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = IndirectConv.plan(&ConvContext::default(), &shape, &kernel);
        let table_bytes = shape.oh() * 3 * 3 * std::mem::size_of::<usize>();
        assert!(plan.resident_bytes() >= table_bytes);
        assert_eq!(plan.workspace_elems(), IndirectConv.workspace_elems(&shape));
    }
}
