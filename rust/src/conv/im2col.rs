//! im2col-based convolution (paper Fig. 1b) — the `Conv.cpu`/`Conv.gpu`
//! baseline.
//!
//! Lowers the input into a Toeplitz matrix L of shape
//! `i_n·o_h·o_w × k_h·k_w·i_c` (Eq. 2) — each output position's receptive
//! field linearized into one row — then computes `O = L × K` with a single
//! big GEMM. The memory-overhead is exactly `|L|`, which is what MEC
//! attacks: every input pixel is replicated up to `k_h·k_w / (s_h·s_w)`
//! times.
//!
//! Plan/execute: the kernel matrix K is the GEMM's B-operand and is
//! input-independent, so the plan packs it once ([`PackedKernel`], shared
//! across a layer's per-batch-size plans); execute lowers into the arena
//! and runs one prepacked GEMM.
//!
//! Precision: under [`Precision::Q16`](crate::tensor::quant::Precision)
//! the kernel is quantized at plan time and the lowering quantizes while
//! it copies (the activation scale comes from a per-execute abs-max), so
//! L occupies **half** the bytes — the paper's fixed-point grid riding
//! the same compact lowering.

use super::{
    downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack, PackedKernel,
};
use crate::gemm::{
    gemm_prepacked_ex, gemm_prepacked_ex_i16, KernelBackend, MatMut, MatRef, MatRefI16,
    Q16Epilogue,
};
use crate::memory::WorkspaceLayout;
use crate::threadpool::Parallelism;
use crate::tensor::quant::{f32_as_i16_mut, i16_slots, Precision, QParams};
use crate::tensor::{ConvShape, Kernel, Tensor};
use std::sync::Arc;

pub struct Im2col;

impl Im2col {
    /// Fill the lowered matrix. Exposed for the lowering-only benchmark
    /// (Fig. 4f's "MEC lowers 85% faster" claim compares this loop with
    /// MEC's).
    pub fn lower(ctx: &ConvContext, shape: &ConvShape, input: &Tensor, l: &mut [f32]) {
        let s = *shape;
        let (oh, ow) = (s.oh(), s.ow());
        let k = s.kernel;
        let ish = s.input;
        let row_len = k.kh * k.kw * k.ic;
        assert_eq!(l.len(), ish.n * oh * ow * row_len);
        let in_data = input.data();
        let lp = crate::threadpool::SharedSlice::new(l);

        // One task per lowered row (= one output position): rows are
        // disjoint, copies are k_w·i_c contiguous runs. Grain: each row
        // moves row_len floats (read + write).
        ctx.par.parallel_for_bytes(ish.n * oh * ow, row_len * 8, |r| {
            let l_data: &mut [f32] = lp.slice();
            let n = r / (oh * ow);
            let y = (r / ow) % oh;
            let x = r % ow;
            let row = &mut l_data[r * row_len..(r + 1) * row_len];
            for u in 0..k.kh {
                let src_off = ish.index(n, y * s.sh + u, x * s.sw, 0);
                let dst_off = u * k.kw * k.ic;
                row[dst_off..dst_off + k.kw * k.ic]
                    .copy_from_slice(&in_data[src_off..src_off + k.kw * k.ic]);
            }
        });
    }

    /// Quantizing variant of [`Im2col::lower`]: identical walk, but each
    /// copied element is quantized into the i16 L with `qp`'s scale —
    /// the lowering already streams every element once, so quantization
    /// rides the same pass for free.
    pub fn lower_q16(
        ctx: &ConvContext,
        shape: &ConvShape,
        input: &Tensor,
        qp: QParams,
        l: &mut [i16],
    ) {
        let s = *shape;
        let (oh, ow) = (s.oh(), s.ow());
        let k = s.kernel;
        let ish = s.input;
        let row_len = k.kh * k.kw * k.ic;
        assert_eq!(l.len(), ish.n * oh * ow * row_len);
        let in_data = input.data();
        let lp = crate::threadpool::SharedSlice::new(l);

        // Grain: each row reads row_len f32 and writes row_len i16.
        ctx.par.parallel_for_bytes(ish.n * oh * ow, row_len * 6, |r| {
            let l_data: &mut [i16] = lp.slice();
            let n = r / (oh * ow);
            let y = (r / ow) % oh;
            let x = r % ow;
            let row = &mut l_data[r * row_len..(r + 1) * row_len];
            for u in 0..k.kh {
                let src_off = ish.index(n, y * s.sh + u, x * s.sw, 0);
                let dst_off = u * k.kw * k.ic;
                for (d, &v) in row[dst_off..dst_off + k.kw * k.ic]
                    .iter_mut()
                    .zip(&in_data[src_off..src_off + k.kw * k.ic])
                {
                    *d = qp.quantize(v);
                }
            }
        });
    }
}

impl Convolution for Im2col {
    fn name(&self) -> &'static str {
        "im2col"
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    /// Eq. (2): `i_n·o_h·o_w · k_h·k_w·i_c` floats.
    fn workspace_elems(&self, shape: &ConvShape) -> usize {
        shape.im2col_lowered_elems()
    }

    /// Under q16 the lowered matrix is stored in i16 lanes: half the
    /// Eq. 2 bytes (rounded up to a whole f32 slot) — exactly the plan's
    /// layout, so budget admission sees the real fixed-point footprint.
    fn workspace_bytes_prec(&self, shape: &ConvShape, precision: Precision) -> usize {
        match precision {
            Precision::F32 => self.workspace_bytes(shape),
            Precision::Q16 => i16_slots(shape.im2col_lowered_elems()) * 4,
        }
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        Arc::new(PackedKernel::pack(ctx, shape, kernel))
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let packed_k: Arc<PackedKernel> = downcast_prepack(prepack, "im2col");
        let mut layout = WorkspaceLayout::new();
        match &*packed_k {
            PackedKernel::F32(_) => {
                layout.push("lowered", shape.im2col_lowered_elems());
            }
            PackedKernel::Q16 { .. } => {
                // i16 lanes inside the f32 arena: half the bytes of Eq. 2.
                layout.push_i16("lowered", shape.im2col_lowered_elems());
            }
        }
        Box::new(Im2colPlan {
            ctx: ctx.clone(),
            shape: *shape,
            packed_k,
            layout,
        })
    }
}

/// Plan for im2col: prepacked kernel matrix (shared, precision-resolved)
/// + the Eq. (2) lowered-matrix region.
pub struct Im2colPlan {
    ctx: ConvContext,
    shape: ConvShape,
    packed_k: Arc<PackedKernel>,
    layout: WorkspaceLayout,
}

impl ConvPlan for Im2colPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Im2col
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.packed_k.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.packed_k) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        Some(self.packed_k.backend())
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl Im2colPlan {
    /// The execute body, parameterized on the context so per-session
    /// thread caps reuse the same path as the plan-default execute.
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        let k = s.kernel;
        let rows = s.input.n * s.oh() * s.ow();
        let row_len = k.kh * k.kw * k.ic;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), s.input);

        match &*self.packed_k {
            PackedKernel::F32(pk) => {
                let l = &mut scratch[..rows * row_len];
                Im2col::lower(ctx, &s, input, l);

                // O (i_n·o_h·o_w × k_c, row-major NHWC is exactly this
                // matrix) = L (rows × row_len) × K (row_len × k_c).
                let a = MatRef::new(l, rows, row_len);
                let mut c = MatMut::new(output.data_mut(), rows, k.kc);
                gemm_prepacked_ex(a, pk, &mut c, &ctx.par);
            }
            PackedKernel::Q16 { packed, col_scales } => {
                // Calibrated static activation scale when available (the
                // serving fast path), dynamic abs-max otherwise; then
                // quantize-while-lowering into the halved i16 L and run
                // the widening GEMM. The epilogue folds the Q15 product
                // shift back out globally and applies each output
                // channel's own kernel scale per column.
                let qa = ctx
                    .act_qparams
                    .unwrap_or_else(|| QParams::from_slice(input.data()));
                let slots = i16_slots(rows * row_len);
                let l = &mut f32_as_i16_mut(&mut scratch[..slots])[..rows * row_len];
                Im2col::lower_q16(ctx, &s, input, qa, l);

                let a = MatRefI16::new(l, rows, row_len);
                let mut c = MatMut::new(output.data_mut(), rows, k.kc);
                let ep = Q16Epilogue {
                    global: qa.scale * 32768.0,
                    per_col: Some(col_scales),
                };
                gemm_prepacked_ex_i16(a, packed, &mut c, ep, &ctx.par);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    #[test]
    fn lowered_matrix_matches_fig1b() {
        // Paper Fig. 1: 7x7 input, 3x3 kernel, s=1 -> L is 25x9.
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let input = Tensor::from_fn(shape.input, |_, h, w, _| (h * 7 + w) as f32);
        let mut l = vec![0.0; shape.im2col_lowered_elems()];
        assert_eq!(l.len(), 25 * 9);
        Im2col::lower(&ConvContext::default(), &shape, &input, &mut l);
        // Row 0 = input[0:3, 0:3] linearized.
        assert_eq!(&l[0..9], &[0., 1., 2., 7., 8., 9., 14., 15., 16.]);
        // Row 1 = window slid by s_w=1.
        assert_eq!(&l[9..18], &[1., 2., 3., 8., 9., 10., 15., 16., 17.]);
        // Row 5 = window slid down by s_h=1 (first of second output row).
        assert_eq!(&l[5 * 9..5 * 9 + 3], &[7., 8., 9.]);
    }

    #[test]
    fn matches_direct_on_random_geometries() {
        let mut rng = Rng::new(21);
        for (n, ih, iw, ic, kh, kw, kc, sh, sw) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1),
            (2, 9, 8, 3, 3, 2, 4, 2, 1),
            (1, 12, 12, 2, 5, 5, 3, 2, 2),
            (3, 6, 6, 4, 1, 1, 8, 1, 1),
            (1, 11, 5, 2, 4, 3, 2, 3, 2),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let ctx = ConvContext::default().with_threads(2);
            let mut want = Tensor::zeros(shape.output());
            let mut got = Tensor::zeros(shape.output());
            let mut ws = Workspace::new();
            Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
            Im2col.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
            assert_allclose(got.data(), want.data(), 1e-4, &shape.describe());
        }
    }

    #[test]
    fn workspace_matches_eq2() {
        // cv1 geometry: 227x227x3, 11x11x96, s=4 -> o=55.
        let shape = ConvShape::new(
            Nhwc::new(1, 227, 227, 3),
            KernelShape::new(11, 11, 3, 96),
            4,
            4,
        );
        assert_eq!(shape.oh(), 55);
        assert_eq!(Im2col.workspace_elems(&shape), 55 * 55 * 11 * 11 * 3);
    }

    #[test]
    fn plan_layout_is_the_lowered_matrix() {
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = Im2col.plan(&ConvContext::default(), &shape, &kernel);
        assert_eq!(plan.workspace_elems(), shape.im2col_lowered_elems());
        assert_eq!(
            plan.layout().region("lowered").unwrap().elems,
            shape.im2col_lowered_elems()
        );
    }

    #[test]
    fn q16_plan_halves_the_lowered_region() {
        let shape = ConvShape::new(Nhwc::new(2, 9, 8, 3), KernelShape::new(3, 2, 3, 4), 2, 1);
        let mut rng = Rng::new(0x60);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let fplan = Im2col.plan(&ConvContext::default(), &shape, &kernel);
        let qplan = Im2col.plan(
            &ConvContext::default().with_precision(Precision::Q16),
            &shape,
            &kernel,
        );
        let fl = fplan.layout().region("lowered").unwrap().elems;
        let ql = qplan.layout().region("lowered").unwrap().elems;
        assert_eq!(ql, fl.div_ceil(2));
    }

    #[test]
    fn q16_matches_direct_within_quantization_noise() {
        let shape = ConvShape::new(Nhwc::new(2, 10, 9, 3), KernelShape::new(3, 3, 3, 5), 1, 2);
        let mut rng = Rng::new(0x61);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut want = Tensor::zeros(shape.output());
        Direct.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut want,
        );
        for threads in [1usize, 3] {
            let ctx = ConvContext::default()
                .with_threads(threads)
                .with_precision(Precision::Q16);
            let plan = Im2col.plan(&ctx, &shape, &kernel);
            // Plain Vec scratch (not a tracked Arena): unit tests must not
            // perturb the global tracker the memory tests assert against.
            let mut scratch = vec![0.0f32; plan.workspace_elems()];
            let mut got = Tensor::zeros(shape.output());
            plan.execute_in(&input, &mut scratch, &mut got);
            assert_allclose(got.data(), want.data(), 1e-3, &format!("q16 t={threads}"));
        }
    }
}
