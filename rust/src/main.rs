//! `mec` — CLI for the MEC convolution engine + serving runtime.
//!
//! Subcommands:
//! * `info`  — workloads, algorithms, platform.
//! * `run`   — execute one benchmark layer with one algorithm; print
//!             runtime and measured/analytic memory overhead.
//! * `plan`  — show the planner's choice for a layer under a budget.
//! * `tune`  — measure all admissible algorithms on a layer.
//! * `serve` — load a `.mecw` model and serve synthetic requests through
//!             the coordinator, printing latency/throughput metrics.

use mec::bench::workload::{by_name, suite};
use mec::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::coordinator::{BatchPolicy, Server, ServerConfig};
use mec::memory::{measure_peak, Arena, Budget};
use mec::model::load_mecw;
use mec::planner::{AutoTuner, Planner};
use mec::tensor::{Kernel, Precision, Tensor};
use mec::util::cli::Args;
use mec::util::stats::{fmt_bytes, fmt_ns};
use mec::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    mec::util::logging::init();
    let mut args = Args::from_env(
        "MEC: memory-efficient convolution engine (ICML'17 reproduction).\n\
         Subcommands: info | run | plan | tune | serve",
    );
    match args.subcommand().unwrap_or("info") {
        "info" => cmd_info(),
        "run" => cmd_run(&mut args),
        "plan" => cmd_plan(&mut args),
        "tune" => cmd_tune(&mut args),
        "serve" => cmd_serve(&mut args),
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

fn parse_budget(s: &str) -> Budget {
    if s == "unlimited" {
        return Budget::unlimited();
    }
    let (num, mult) = if let Some(v) = s.strip_suffix("GB") {
        (v, 1_000_000_000)
    } else if let Some(v) = s.strip_suffix("MB") {
        (v, 1_000_000)
    } else if let Some(v) = s.strip_suffix("KB") {
        (v, 1_000)
    } else {
        (s, 1)
    };
    match num.parse::<f64>() {
        Ok(v) => Budget::new((v * mult as f64) as usize),
        Err(_) => {
            eprintln!("bad budget {s:?} (use e.g. 16MB, 1.5GB, unlimited)");
            std::process::exit(2);
        }
    }
}

fn cmd_info() {
    println!("MEC engine — paper workloads (Table 2):");
    println!(
        "{:<6} {:>14} {:>12} {:>4} {:>10} {:>12} {:>12}",
        "name", "input", "kernel", "s", "k/s", "im2col MB", "MEC MB"
    );
    for w in suite() {
        let s = w.shape(1, 1);
        println!(
            "{:<6} {:>14} {:>12} {:>4} {:>10.2} {:>12} {:>12}",
            w.name,
            format!("{}x{}x{}", w.ih, w.iw, w.ic),
            format!("{}x{}x{}", w.kh, w.kw, w.kc),
            w.s,
            w.k_over_s(),
            fmt_bytes(s.im2col_lowered_elems() * 4),
            fmt_bytes(s.mec_lowered_elems() * 4),
        );
    }
    println!("\nalgorithms: direct im2col mec mec-a mec-b winograd fft");
    println!(
        "host threads: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
}

fn precision_arg(args: &mut Args) -> Precision {
    let p = args.opt("precision", "f32", "execution precision (f32|q16)");
    match Precision::parse(&p) {
        Some(v) => v,
        None => {
            eprintln!("unknown precision {p:?} (expected f32 or q16)");
            std::process::exit(2);
        }
    }
}

fn layer_arg(args: &mut Args) -> mec::tensor::ConvShape {
    let layer = args.opt("layer", "cv6", "benchmark layer (cv1..cv12)");
    let batch = args.opt_usize("batch", 1, "mini-batch size");
    let scale = args.opt_usize("scale", 1, "channel divisor (1 = paper-exact)");
    match by_name(&layer) {
        Some(w) => w.shape(batch, scale),
        None => {
            eprintln!("unknown layer {layer:?} (cv1..cv12)");
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &mut Args) {
    let shape = layer_arg(args);
    let algo_s = args.opt("algo", "mec", "algorithm (direct|im2col|mec|mec-a|mec-b|winograd|fft)");
    let threads = args.opt_usize("threads", 1, "worker threads");
    let reps = args.opt_usize("reps", 3, "timed repetitions");
    let precision = precision_arg(args);
    args.finish();
    let kind: AlgoKind = match algo_s.parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let algo = kind.build();
    if !algo.supports(&shape) {
        eprintln!("{} does not support {}", algo.name(), shape.describe());
        std::process::exit(1);
    }
    if !kind.supports_precision(precision) {
        eprintln!("{} has no {precision} path (q16 covers direct/im2col/mec)", algo.name());
        std::process::exit(1);
    }
    let ctx = ConvContext::default()
        .with_threads(threads)
        .with_precision(precision);
    let mut rng = Rng::new(42);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let mut out = Tensor::zeros(shape.output());

    // Plan once (model-load cost), then measure steady-state executes
    // against a planner-sized arena — the serving hot path.
    let t_plan = Instant::now();
    let plan = algo.plan(&ctx, &shape, &kernel);
    let plan_ns = t_plan.elapsed().as_nanos() as f64;
    let ((), peak) = measure_peak(|| {
        let mut arena = Arena::with_capacity(plan.workspace_elems());
        plan.execute(&input, &mut arena, &mut out);
    });
    let mut arena = Arena::with_capacity(plan.workspace_elems());
    plan.execute(&input, &mut arena, &mut out); // warm
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        plan.execute(&input, &mut arena, &mut out);
        best = best.min(t0.elapsed().as_nanos() as f64);
    }
    println!("layer    : {}", shape.describe());
    println!("algorithm: {}", algo.name());
    println!("precision: {precision}");
    println!("plan     : {} (one-time: dispatch + kernel prepack/transform)", fmt_ns(plan_ns));
    println!("execute  : {} (best of {reps}, {threads} threads, plan-amortized)", fmt_ns(best));
    println!(
        "overhead : measured {} / plan layout {} / analytic {}",
        fmt_bytes(peak),
        fmt_bytes(plan.workspace_bytes()),
        fmt_bytes(algo.workspace_bytes(&shape))
    );
    println!("gflops   : {:.2}", shape.flops() as f64 / best);
}

fn cmd_plan(args: &mut Args) {
    let shape = layer_arg(args);
    let budget = parse_budget(&args.opt("budget", "unlimited", "workspace budget (e.g. 16MB)"));
    let threads = args.opt_usize("threads", 1, "worker threads");
    let precision = precision_arg(args);
    args.finish();
    let planner = Planner::new();
    let ctx = ConvContext::default()
        .with_threads(threads)
        .with_precision(precision);
    println!("layer: {}", shape.describe());
    println!("precision: {precision}");
    println!(
        "budget: {}",
        if budget.limit() == usize::MAX {
            "unlimited".into()
        } else {
            fmt_bytes(budget.limit())
        }
    );
    println!("\nadmissible plans:");
    for p in planner.admissible(&shape, &budget, &ctx) {
        println!(
            "  {:<10} workspace={:>12} est={:>12}",
            p.algo.name(),
            fmt_bytes(p.workspace_bytes),
            fmt_ns(p.est_ns)
        );
    }
    let chosen = planner.plan(&shape, &budget, &ctx);
    println!(
        "\nchosen: {} ({} workspace)",
        chosen.algo.name(),
        fmt_bytes(chosen.workspace_bytes)
    );
}

fn cmd_tune(args: &mut Args) {
    let shape = layer_arg(args);
    let budget = parse_budget(&args.opt("budget", "unlimited", "workspace budget"));
    let threads = args.opt_usize("threads", 1, "worker threads");
    let precision = precision_arg(args);
    args.finish();
    let tuner = AutoTuner::new();
    let ctx = ConvContext::default()
        .with_threads(threads)
        .with_precision(precision);
    println!(
        "measuring on {} ({precision}, plan-amortized) ...",
        shape.describe()
    );
    let mut ms = tuner.measure_all(&shape, &budget, &ctx);
    ms.sort_by(|a, b| a.median_ns.partial_cmp(&b.median_ns).unwrap());
    for m in &ms {
        println!(
            "  {:<10} execute {:>12}  plan {:>12}  workspace={}",
            m.algo.name(),
            fmt_ns(m.median_ns),
            fmt_ns(m.plan_ns),
            fmt_bytes(m.workspace_bytes)
        );
    }
    println!("winner: {}", ms[0].algo.name());
}

fn cmd_serve(args: &mut Args) {
    let model_path = args.opt("model", "artifacts/model.mecw", "path to .mecw weights");
    let requests = args.opt_usize("requests", 256, "synthetic requests to send");
    let workers = args.opt_usize("workers", 1, "server worker threads");
    let max_batch = args.opt_usize("max-batch", 32, "dynamic batch cap");
    let delay_ms = args.opt_usize("max-delay-ms", 2, "dynamic batch delay");
    let budget = parse_budget(&args.opt("budget", "unlimited", "conv workspace budget"));
    let threads = args.opt_usize("threads", 1, "engine threads per worker");
    let precision = precision_arg(args);
    args.finish();

    let mut model = match load_mecw(&model_path) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot load model {model_path:?}: {e}\n(run `make artifacts` first)");
            std::process::exit(1);
        }
    };
    let ctx = ConvContext::default()
        .with_threads(threads)
        .with_precision(precision);
    model.plan(&Planner::new(), &budget, &ctx, max_batch);
    println!(
        "model {:?}: {} layers, {} params, plans: {:?}",
        model.name,
        model.layers.len(),
        model.param_count(),
        model
            .plan_summary()
            .iter()
            .map(|(i, a)| format!("L{i}:{}", a.name()))
            .collect::<Vec<_>>()
    );
    println!(
        "shared arena: {} per worker (max over planned layers, not sum)",
        fmt_bytes(model.planned_workspace_bytes())
    );
    let (h, w, c) = model.input_hwc;
    let server = Server::start(
        Arc::new(model),
        ServerConfig {
            workers,
            queue_capacity: 1024,
            policy: BatchPolicy::new(max_batch, Duration::from_millis(delay_ms as u64)),
            ctx,
        },
    );
    let client = server.client();
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for _ in 0..requests {
        let mut sample = vec![0.0f32; h * w * c];
        rng.fill_uniform(&mut sample, 0.0, 1.0);
        match client.submit(sample) {
            Ok(rx) => pending.push(rx),
            Err(e) => mec::log_warn!("request rejected: {e}"),
        }
    }
    let mut served = 0;
    for rx in pending {
        if rx.recv().is_ok() {
            served += 1;
        }
    }
    let metrics = server.shutdown();
    println!("\nserved {served}/{requests}");
    println!("{}", metrics.report());
}
