//! `mec` — CLI for the MEC convolution engine + serving runtime.
//!
//! Subcommands:
//! * `info`  — workloads, algorithms, platform.
//! * `run`   — execute one benchmark layer with one algorithm; print
//!             runtime and measured/planned memory overhead.
//! * `plan`  — show the planner's choice for a layer under a budget.
//! * `tune`  — measure all admissible algorithms on a layer.
//! * `serve` — load a `.mecw` model and serve synthetic requests through
//!             the coordinator, printing latency/throughput metrics.
//!
//! Every subcommand is argument parsing + an [`Engine::builder`] call:
//! the builder validates the whole configuration (algorithm, precision,
//! budget, threads, batch) up front and returns a typed
//! [`EngineError`], so this file owns the exit codes and nothing else.

use mec::bench::harness::layer_builder;
use mec::bench::workload::{by_name, suite, Workload};
use mec::conv::AlgoKind;
use mec::coordinator::{Server, ServerConfig};
use mec::engine::{Engine, EngineError};
use mec::memory::{measure_peak, Budget};
use mec::serving::SloMs;
use mec::tensor::{Precision, Tensor};
use mec::util::cli::Args;
use mec::util::stats::{fmt_bytes, fmt_ns};
use mec::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    mec::util::logging::init();
    let mut args = Args::from_env(
        "MEC: memory-efficient convolution engine (ICML'17 reproduction).\n\
         Subcommands: info | run | plan | tune | serve",
    );
    match args.subcommand().unwrap_or("info") {
        "info" => cmd_info(),
        "run" => cmd_run(&mut args),
        "plan" => cmd_plan(&mut args),
        "tune" => cmd_tune(&mut args),
        "serve" => cmd_serve(&mut args),
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

fn cmd_info() {
    println!("MEC engine — paper workloads (Table 2):");
    println!(
        "{:<6} {:>14} {:>12} {:>4} {:>10} {:>12} {:>12}",
        "name", "input", "kernel", "s", "k/s", "im2col MB", "MEC MB"
    );
    for w in suite() {
        let s = w.shape(1, 1);
        println!(
            "{:<6} {:>14} {:>12} {:>4} {:>10.2} {:>12} {:>12}",
            w.name,
            format!("{}x{}x{}", w.ih, w.iw, w.ic),
            format!("{}x{}x{}", w.kh, w.kw, w.kc),
            w.s,
            w.k_over_s(),
            fmt_bytes(s.im2col_lowered_elems() * 4),
            fmt_bytes(s.mec_lowered_elems() * 4),
        );
    }
    println!("\nalgorithms: direct im2col mec mec-a mec-b winograd fft indirect kn2row smm");
    println!("extra workloads (non-paper, cost-model anchors): pw1 pw2");
    println!(
        "host threads: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
}

fn precision_arg(args: &mut Args) -> Precision {
    let p = args.opt("precision", "f32", "execution precision (f32|q16)");
    match Precision::parse(&p) {
        Some(v) => v,
        None => {
            eprintln!("unknown precision {p:?} (expected f32 or q16)");
            std::process::exit(2);
        }
    }
}

fn budget_arg(args: &mut Args, help: &str) -> Budget {
    let s = args.opt("budget", "unlimited", help);
    match s.parse::<Budget>() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// `--layer/--batch/--scale` → the named paper workload.
fn workload_args(args: &mut Args) -> (Workload, usize, usize) {
    let layer = args.opt("layer", "cv6", "benchmark layer (cv1..cv12, pw1, pw2)");
    let batch = args.opt_usize("batch", 1, "mini-batch size");
    let scale = args.opt_usize("scale", 1, "channel divisor (1 = paper-exact)");
    match by_name(&layer) {
        Some(w) => (w, batch.max(1), scale),
        None => {
            eprintln!("unknown layer {layer:?} (cv1..cv12, pw1, pw2)");
            std::process::exit(2);
        }
    }
}

fn fmt_budget(b: &Budget) -> String {
    if b.limit() == usize::MAX {
        "unlimited".into()
    } else {
        fmt_bytes(b.limit())
    }
}

fn exit_engine_err<T>(e: EngineError) -> T {
    eprintln!("{e}");
    std::process::exit(1);
}

fn cmd_run(args: &mut Args) {
    let (w, batch, scale) = workload_args(args);
    let algo_s = args.opt(
        "algo",
        "mec",
        "algorithm (direct|im2col|mec|mec-a|mec-b|winograd|fft|indirect|kn2row|smm)",
    );
    let threads = args.opt_usize("threads", 1, "worker threads");
    let reps = args.opt_usize("reps", 3, "timed repetitions");
    let precision = precision_arg(args);
    args.finish();
    let kind: AlgoKind = match algo_s.parse() {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let shape = w.shape(batch, scale);
    // Synthesizing the layer's random weights is not part of the build
    // cost a real deployment pays — keep it outside the timed region.
    let builder = layer_builder(&w, batch, scale)
        .threads(threads)
        .precision(precision)
        .algo_override(0, kind);
    // Unsupported geometry/precision surfaces here as a typed error —
    // not as a panic three layers down.
    let t_build = Instant::now();
    let engine = builder.build().unwrap_or_else(exit_engine_err);
    let build_ns = t_build.elapsed().as_nanos() as f64;
    let mut rng = Rng::new(42);
    let input = Tensor::random(shape.input, &mut rng);
    // Peak temporary memory = the session arena growing to the engine's
    // planned layout on first use...
    let (mut session, peak) = measure_peak(|| {
        let mut s = engine.session();
        s.infer_batch(&input).expect("input matches engine");
        s
    });
    // ...and runtime in the steady state (plan-amortized serving cost).
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        session.infer_batch(&input).expect("input matches engine");
        best = best.min(t0.elapsed().as_nanos() as f64);
    }
    let report = &engine.plan_report()[0];
    println!("layer    : {}", shape.describe());
    println!("algorithm: {}", kind.name());
    println!("precision: {precision}");
    println!(
        "kernel   : {} ({}x{})",
        report.backend.name(),
        mec::gemm::micro::MR,
        report.backend.nr()
    );
    println!(
        "build    : {} (one-time: validate + plan + kernel prepack)",
        fmt_ns(build_ns)
    );
    println!(
        "execute  : {} (best of {reps}, {threads} threads, plan-amortized)",
        fmt_ns(best)
    );
    println!(
        "overhead : measured {} / engine arena {} / planner {}",
        fmt_bytes(peak),
        fmt_bytes(engine.workspace_bytes()),
        fmt_bytes(report.chosen.workspace_bytes)
    );
    println!("gflops   : {:.2}", shape.flops() as f64 / best);
}

fn cmd_plan(args: &mut Args) {
    let (w, batch, scale) = workload_args(args);
    let budget = budget_arg(args, "workspace budget (e.g. 16MB)");
    let threads = args.opt_usize("threads", 1, "worker threads");
    let precision = precision_arg(args);
    args.finish();
    let engine = layer_builder(&w, batch, scale)
        .threads(threads)
        .precision(precision)
        .budget(budget.clone())
        .build()
        .unwrap_or_else(exit_engine_err);
    let report = &engine.plan_report()[0];
    println!("layer: {}", report.shape.describe());
    println!("precision: {precision}");
    println!(
        "kernel: {} ({}x{})",
        report.backend.name(),
        mec::gemm::micro::MR,
        report.backend.nr()
    );
    println!("budget: {}", fmt_budget(&budget));
    println!("\nadmissible plans:");
    for p in &report.candidates {
        println!(
            "  {:<10} workspace={:>12} est={:>12}",
            p.algo.name(),
            fmt_bytes(p.workspace_bytes),
            fmt_ns(p.est_ns)
        );
    }
    println!(
        "\nchosen: {} ({} workspace)",
        report.chosen.algo.name(),
        fmt_bytes(report.chosen.workspace_bytes)
    );
}

fn cmd_tune(args: &mut Args) {
    let (w, batch, scale) = workload_args(args);
    let budget = budget_arg(args, "workspace budget");
    let threads = args.opt_usize("threads", 1, "worker threads");
    let precision = precision_arg(args);
    args.finish();
    println!(
        "measuring on {} ({precision}, {} {}x{} kernel, plan-amortized) ...",
        w.shape(batch, scale).describe(),
        mec::gemm::KernelBackend::active().name(),
        mec::gemm::micro::MR,
        mec::gemm::KernelBackend::active().nr()
    );
    let engine = layer_builder(&w, batch, scale)
        .threads(threads)
        .precision(precision)
        .budget(budget)
        .autotune(true)
        .build()
        .unwrap_or_else(exit_engine_err);
    let report = &engine.plan_report()[0];
    let mut ms = report
        .measurements
        .clone()
        .expect("autotune build records measurements");
    ms.sort_by(|a, b| a.median_ns.total_cmp(&b.median_ns));
    for m in &ms {
        println!(
            "  {:<10} execute {:>12}  plan {:>12}  workspace={}",
            m.algo.name(),
            fmt_ns(m.median_ns),
            fmt_ns(m.plan_ns),
            fmt_bytes(m.workspace_bytes)
        );
    }
    println!("winner: {}", report.chosen.algo.name());
}

fn cmd_serve(args: &mut Args) {
    let model_path = args.opt("model", "artifacts/model.mecw", "path to .mecw weights");
    let requests = args.opt_usize("requests", 256, "synthetic requests to send");
    let workers = args.opt_usize("workers", 1, "server worker threads");
    let max_batch = args.opt_usize("max-batch", 32, "largest pinned batch size");
    let delay_ms = args.opt_usize("max-delay-ms", 2, "batcher collect window");
    let slo = args.opt(
        "slo-ms",
        "none",
        "latency SLO in ms (deadline per request; \"none\" = best-effort)",
    );
    let queue_depth = args.opt_usize("queue-depth", 1024, "bounded request-queue capacity");
    let health_ms = args.opt_usize(
        "health-interval",
        0,
        "print a fault-domain health snapshot every N ms while serving (0 = off)",
    );
    let budget = budget_arg(args, "conv workspace budget");
    let threads = args.opt_usize(
        "threads",
        1,
        "engine thread budget (one shared pool, divided across workers)",
    );
    let precision = precision_arg(args);
    args.finish();
    let slo: SloMs = slo.parse().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    // Pin powers of two up to the batch cap: the adaptive batcher only
    // dispatches pinned shapes, so a denser ladder means less work runs
    // at size 1 when a collect lands between powers.
    let mut pinned = vec![1usize];
    while *pinned.last().unwrap() < max_batch.max(1) {
        pinned.push((pinned.last().unwrap() * 2).min(max_batch.max(1)));
    }
    // The engine caches at most 8 pinned geometries per layer; thin the
    // ladder from the small end (keeping 1 for padding-free splits).
    while pinned.len() > 8 {
        pinned.remove(1);
    }
    let engine = Engine::builder(model_path)
        .budget(budget)
        .threads(threads)
        .precision(precision)
        .pin_batch_sizes(&pinned)
        .build()
        .unwrap_or_else(|e| {
            if matches!(e, EngineError::ModelLoad { .. }) {
                eprintln!("{e}\n(run `make artifacts` first)");
                std::process::exit(1);
            }
            exit_engine_err(e)
        });
    let model = engine.model();
    println!(
        "model {:?}: {} nodes, {} params, plans: {:?}",
        model.name,
        model.node_count(),
        model.param_count(),
        engine
            .plan_summary()
            .iter()
            .map(|(i, a)| format!("L{i}:{}", a.name()))
            .collect::<Vec<_>>()
    );
    println!(
        "shared arena: {} per worker (max over planned layers and pinned batches, not sum)",
        fmt_bytes(engine.workspace_bytes())
    );
    let (h, w, c) = engine.input_hwc();
    if let Some(d) = slo.duration() {
        println!("slo: {slo} ms (deadline {d:?} per request)");
    }
    let server = Server::start(
        Arc::new(engine),
        ServerConfig {
            workers,
            queue_depth,
            slo: slo.duration(),
            max_wait: Duration::from_millis(delay_ms as u64),
            ..ServerConfig::default()
        },
    )
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if let Some(plan) = mec::fault::current_plan() {
        println!("fault injection armed — replay with {}", plan.replay_line());
    }
    let client = server.client();
    let mut rng = Rng::new(7);
    let mut served = 0usize;
    let mut shed = 0usize;
    // The health printer borrows the server while the main thread
    // submits and drains, so it runs in a scope joined before shutdown.
    std::thread::scope(|s| {
        let stop = &std::sync::atomic::AtomicBool::new(false);
        let server = &server;
        if health_ms > 0 {
            s.spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(health_ms as u64));
                    println!("health: {}", server.health());
                }
            });
        }
        let mut pending = Vec::new();
        for _ in 0..requests {
            let mut sample = vec![0.0f32; h * w * c];
            rng.fill_uniform(&mut sample, 0.0, 1.0);
            match client.submit(sample) {
                Ok(rx) => pending.push(rx),
                Err(mec::coordinator::SubmitError::Shed(reason)) => {
                    shed += 1;
                    mec::log_warn!("request shed: {reason}");
                }
                Err(e) => mec::log_warn!("request rejected: {e}"),
            }
        }
        for rx in pending {
            if let Ok(resp) = rx.recv() {
                if resp.result.is_ok() {
                    served += 1;
                }
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Release);
    });
    println!("health: {}", server.health());
    let metrics = server.shutdown();
    println!("\nserved {served}/{requests} (shed at submit: {shed})");
    println!("{}", metrics.snapshot().render());
    println!("{}", metrics.report());
}
