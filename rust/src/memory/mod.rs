//! Memory-overhead accounting — the paper's headline metric.
//!
//! "Memory-overhead" in the paper (Fig. 4b/e, Table 3) is the temporary
//! storage an algorithm needs *beyond* the input I, kernel K, and output O
//! (the lowered matrix L for im2col/MEC, transformed tiles for Winograd,
//! padded spectra for FFT). This module provides:
//!
//! * [`tracker`] — a global byte counter with peak tracking, so benches
//!   report *measured* overhead and tests assert it equals the analytic
//!   Eq. (2)/Eq. (3) formulas.
//! * [`Workspace`] — a tracked, reusable scratch allocation handed to the
//!   conv algorithms (mirrors cuDNN's explicit workspace API, which is the
//!   deployment model for memory-constrained devices the paper targets).
//! * [`Arena`] / [`WorkspaceLayout`] — the plan/execute split's memory
//!   model: each `ConvPlan` emits a layout of named offsets into a single
//!   buffer, and one arena sized at the max over planned layers serves the
//!   whole model (see `ARCHITECTURE.md`).
//! * [`Budget`] — an enforced cap used by the planner to reject algorithms
//!   whose workspace would exceed the device budget.

pub mod activation;
pub mod aligned;
pub mod arena;
pub mod tracker;

pub use activation::ActivationArena;
pub use aligned::{AlignedVec, ALIGN};
pub use arena::{Arena, Region, WorkspaceLayout};
pub use tracker::{current_bytes, peak_bytes, MeasureScope};

use std::sync::atomic::Ordering;

/// Bit pattern of the debug-build poison sentinel: a quiet NaN with a
/// recognizable `DEAD` payload. Freshly taken non-zeroed scratch
/// ([`Workspace::take_uninit`], [`Workspace::take_split`],
/// [`Arena::slice`]) is filled with this value in debug builds, so any
/// consumer that reads scratch before writing it produces NaNs that
/// propagate straight into the correctness suites instead of silently
/// reusing stale data. Release builds skip the fill — the non-zeroing
/// fast path is the whole point of these accessors.
pub const POISON_BITS: u32 = 0x7FC0_DEAD;

/// The poison sentinel as an `f32` (see [`POISON_BITS`]).
pub fn poison() -> f32 {
    f32::from_bits(POISON_BITS)
}

/// Fill `s` with the poison sentinel in debug builds; no-op in release.
pub(crate) fn poison_fill(s: &mut [f32]) {
    if cfg!(debug_assertions) {
        s.fill(poison());
    }
}

/// A refused allocation, reported as a value instead of an abort.
///
/// Carried up from the `try_*` growth paths ([`Workspace::try_reserve`],
/// [`Arena::try_reserve`], [`ActivationArena::try_ensure`],
/// [`AlignedVec::try_grow`]) so the engine can react — degrade the plan
/// to the zero-workspace family, fail one request with a typed error —
/// rather than taking the whole process down. `site` names the fault
/// domain that refused (also the [`faultpoint!`](crate::faultpoint)
/// site that can inject the refusal deterministically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocError {
    /// Bytes the failed request would have added.
    pub bytes: usize,
    /// The named growth site that refused.
    pub site: &'static str,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "allocation of {} bytes refused at {}", self.bytes, self.site)
    }
}

impl std::error::Error for AllocError {}

/// A tracked scratch buffer of `f32`s. Allocation and release are recorded
/// in the global [`tracker`]; the buffer is reusable across calls (the
/// serving hot path allocates once per worker, then reuses). Storage is
/// 64-byte aligned ([`AlignedVec`]) so the SIMD micro-kernels get aligned
/// loads from lowering buffers carved out of it.
#[derive(Debug)]
pub struct Workspace {
    buf: AlignedVec<f32>,
}

impl Workspace {
    /// Empty workspace (no tracked bytes).
    pub fn new() -> Workspace {
        Workspace {
            buf: AlignedVec::new(),
        }
    }

    /// Workspace pre-sized to `elems` floats.
    pub fn with_capacity(elems: usize) -> Workspace {
        let mut w = Workspace::new();
        w.reserve(elems);
        w
    }

    /// Ensure capacity for `elems` floats, growing (and recording) if
    /// needed. Never shrinks — matching how serving systems hold their
    /// high-water workspace.
    pub fn reserve(&mut self, elems: usize) {
        if elems > self.buf.len() {
            let grow = elems - self.buf.len();
            tracker::track_alloc(grow * 4);
            self.buf.resize(elems, 0.0);
        }
        debug_assert!(
            self.buf.is_empty() || self.buf.as_ptr() as usize % ALIGN == 0,
            "Workspace buffer lost {ALIGN}-byte alignment"
        );
    }

    /// Fallible [`reserve`](Self::reserve): a refused growth (real, or
    /// injected at the `memory.workspace.grow` fault site) comes back as
    /// a typed [`AllocError`] with the workspace unchanged. A request
    /// for zero elements can never fail — zero-workspace plans are
    /// immune by construction.
    pub fn try_reserve(&mut self, elems: usize) -> Result<(), AllocError> {
        if elems > 0 && crate::faultpoint!(alloc "memory.workspace.grow") {
            return Err(AllocError {
                bytes: elems.saturating_sub(self.buf.len()) * 4,
                site: "memory.workspace.grow",
            });
        }
        if elems > self.buf.len() {
            let grow = elems - self.buf.len();
            self.buf.try_resize(elems, 0.0).map_err(|e| AllocError {
                site: "memory.workspace.grow",
                ..e
            })?;
            tracker::track_alloc(grow * 4);
        }
        Ok(())
    }

    /// Borrow the first `elems` floats (must be reserved), zeroed.
    pub fn take_zeroed(&mut self, elems: usize) -> &mut [f32] {
        self.reserve(elems);
        let s = &mut self.buf[..elems];
        s.fill(0.0);
        s
    }

    /// Borrow the first `elems` floats without zeroing (for full-overwrite
    /// consumers like the lowering loops).
    pub fn take(&mut self, elems: usize) -> &mut [f32] {
        self.take_uninit(elems)
    }

    /// Explicitly-named non-zeroing accessor: the returned slice holds
    /// stale contents from previous calls. Use only when every element is
    /// written before being read — true for the im2col/MEC lowering
    /// buffers and all plan workspaces, and worth it: `take_zeroed` on
    /// cv4's lowered matrix would write ~150 MB of zeros per call for
    /// nothing.
    ///
    /// Debug builds poison the returned slice with [`POISON_BITS`] NaNs
    /// so a read-before-write consumer fails loudly (release keeps the
    /// zero-cost contract).
    pub fn take_uninit(&mut self, elems: usize) -> &mut [f32] {
        self.reserve(elems);
        let s = &mut self.buf[..elems];
        poison_fill(s);
        s
    }

    /// Split into two disjoint tracked slices (e.g. lowered matrix + aux).
    /// Non-zeroing like [`Workspace::take_uninit`], with the same
    /// debug-build poison canary on both halves.
    pub fn take_split(&mut self, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
        self.reserve(a + b);
        let (x, rest) = self.buf.split_at_mut(a);
        let y = &mut rest[..b];
        poison_fill(x);
        poison_fill(y);
        (x, y)
    }

    /// Current capacity in floats.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Current capacity in bytes — "memory-overhead" of whoever sized it.
    pub fn bytes(&self) -> usize {
        self.buf.len() * 4
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl Drop for Workspace {
    fn drop(&mut self) {
        tracker::track_free(self.buf.len() * 4);
    }
}

/// A byte budget for temporary memory, enforced by the planner.
#[derive(Debug, Clone)]
pub struct Budget {
    limit: usize,
}

/// Error returned when a requested workspace exceeds the budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BudgetExceeded {
    pub requested: usize,
    pub limit: usize,
}

impl std::fmt::Display for BudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "workspace of {} B exceeds memory budget of {} B",
            self.requested, self.limit
        )
    }
}

impl std::error::Error for BudgetExceeded {}

impl Budget {
    pub fn new(limit_bytes: usize) -> Budget {
        Budget { limit: limit_bytes }
    }

    /// Unlimited budget.
    pub fn unlimited() -> Budget {
        Budget { limit: usize::MAX }
    }

    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Check a request against the budget.
    pub fn check(&self, bytes: usize) -> Result<(), BudgetExceeded> {
        if bytes <= self.limit {
            Ok(())
        } else {
            Err(BudgetExceeded {
                requested: bytes,
                limit: self.limit,
            })
        }
    }

    pub fn allows(&self, bytes: usize) -> bool {
        bytes <= self.limit
    }
}

/// Error from parsing a [`Budget`] string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseBudgetError(pub String);

impl std::fmt::Display for ParseBudgetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bad budget {:?} (use e.g. 500KB, 16MB, 1.5GB, 1048576, or unlimited)",
            self.0
        )
    }
}

impl std::error::Error for ParseBudgetError {}

impl std::str::FromStr for Budget {
    type Err = ParseBudgetError;

    /// Parse a human budget string: `unlimited`, a plain byte count, or
    /// a (possibly fractional) number with a `KB`/`MB`/`GB` suffix
    /// (decimal units, case-insensitive). This lives here — not in the
    /// CLI — so every front end parses budgets identically and none of
    /// them needs a `process::exit` in library-adjacent code.
    fn from_str(s: &str) -> Result<Budget, ParseBudgetError> {
        let lower = s.trim().to_ascii_lowercase();
        if lower == "unlimited" {
            return Ok(Budget::unlimited());
        }
        let (num, mult) = if let Some(v) = lower.strip_suffix("gb") {
            (v, 1e9)
        } else if let Some(v) = lower.strip_suffix("mb") {
            (v, 1e6)
        } else if let Some(v) = lower.strip_suffix("kb") {
            (v, 1e3)
        } else {
            (lower.as_str(), 1.0)
        };
        match num.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => Ok(Budget::new((v * mult) as usize)),
            _ => Err(ParseBudgetError(s.to_string())),
        }
    }
}

/// Convenience: measure the peak tracked overhead while running `f`.
/// Returns `(result, peak_overhead_bytes_during_f)`.
///
/// Measurements are serialized on a global lock: the tracker is a
/// process-wide counter, so two concurrent `measure_peak` calls would
/// see each other's transients (relevant when `cargo test` runs tests
/// in parallel).
pub fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, usize) {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let scope = MeasureScope::begin();
    let out = f();
    let peak = scope.peak();
    (out, peak)
}

/// Global ordering used by the tracker atomics (relaxed is fine — we only
/// need monotone counters, not synchronization).
pub(crate) const ORD: Ordering = Ordering::Relaxed;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_tracks_growth_and_release() {
        let before = current_bytes();
        {
            let mut w = Workspace::new();
            w.reserve(1000);
            assert_eq!(current_bytes(), before + 4000);
            w.reserve(500); // no growth
            assert_eq!(current_bytes(), before + 4000);
            w.reserve(2000); // grows by 1000 floats
            assert_eq!(current_bytes(), before + 8000);
        }
        assert_eq!(current_bytes(), before);
    }

    #[test]
    fn take_zeroed_zeroes() {
        let mut w = Workspace::new();
        w.take(4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.take_zeroed(4), &[0.0; 4]);
    }

    #[test]
    fn take_uninit_does_not_zero_and_poisons_in_debug() {
        let mut w = Workspace::new();
        w.take_uninit(4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let s = w.take_uninit(4);
        if cfg!(debug_assertions) {
            // Debug builds overwrite fresh scratch with the recognizable
            // poison NaN so read-before-write bugs surface immediately.
            assert!(
                s.iter().all(|v| v.to_bits() == POISON_BITS),
                "take_uninit must poison in debug builds, got {s:?}"
            );
        } else {
            // Release: stale contents survive — the zero-cost
            // full-overwrite contract.
            assert_eq!(s, &[1.0, 2.0, 3.0, 4.0]);
        }
    }

    #[test]
    fn take_split_poisons_both_halves_in_debug() {
        let mut w = Workspace::new();
        w.take_uninit(5).fill(7.0);
        let (a, b) = w.take_split(3, 2);
        if cfg!(debug_assertions) {
            assert!(a.iter().chain(b.iter()).all(|v| v.to_bits() == POISON_BITS));
        } else {
            assert_eq!(a, &[7.0; 3]);
            assert_eq!(b, &[7.0; 2]);
        }
    }

    #[test]
    fn take_split_disjoint() {
        let mut w = Workspace::new();
        let (a, b) = w.take_split(3, 2);
        a.fill(1.0);
        b.fill(2.0);
        assert_eq!(a, &[1.0, 1.0, 1.0]);
        assert_eq!(b, &[2.0, 2.0]);
    }

    #[test]
    fn budget_enforced() {
        let b = Budget::new(100);
        assert!(b.check(100).is_ok());
        assert_eq!(
            b.check(101),
            Err(BudgetExceeded {
                requested: 101,
                limit: 100
            })
        );
        assert!(Budget::unlimited().allows(usize::MAX));
    }

    #[test]
    fn budget_parses_suffixes_and_unlimited() {
        assert_eq!("unlimited".parse::<Budget>().unwrap().limit(), usize::MAX);
        assert_eq!("UNLIMITED".parse::<Budget>().unwrap().limit(), usize::MAX);
        assert_eq!("16MB".parse::<Budget>().unwrap().limit(), 16_000_000);
        assert_eq!("1.5GB".parse::<Budget>().unwrap().limit(), 1_500_000_000);
        assert_eq!("500KB".parse::<Budget>().unwrap().limit(), 500_000);
        assert_eq!("  2mb ".parse::<Budget>().unwrap().limit(), 2_000_000);
        // Plain byte counts.
        assert_eq!("1048576".parse::<Budget>().unwrap().limit(), 1_048_576);
        assert_eq!("0".parse::<Budget>().unwrap().limit(), 0);
    }

    #[test]
    fn budget_parse_rejects_bad_inputs() {
        for bad in ["", "MB", "12XB", "abcMB", "-5MB", "-1", "NaNMB", "infGB"] {
            let err = bad.parse::<Budget>();
            assert!(err.is_err(), "{bad:?} should not parse");
            assert!(
                err.unwrap_err().to_string().contains(bad),
                "error names the offending input"
            );
        }
    }

    #[test]
    fn measure_peak_sees_transient() {
        let (_, peak) = measure_peak(|| {
            let mut w = Workspace::with_capacity(256);
            let _ = w.take(256);
        });
        assert!(peak >= 1024, "peak={peak}");
    }
}
