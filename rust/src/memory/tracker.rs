//! Global overhead-byte tracker.
//!
//! Records every tracked temporary allocation (workspaces, lowered
//! matrices, transform buffers) so benches can print **measured** memory
//! overhead next to the paper's analytic formulas. Lock-free atomics; the
//! peak is maintained with a CAS loop.

use super::ORD;
use std::sync::atomic::AtomicUsize;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Record an allocation of `bytes` of temporary memory.
pub fn track_alloc(bytes: usize) {
    let now = CURRENT.fetch_add(bytes, ORD) + bytes;
    // Monotone max via CAS.
    let mut peak = PEAK.load(ORD);
    while now > peak {
        match PEAK.compare_exchange_weak(peak, now, ORD, ORD) {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

/// Record a release of `bytes`.
pub fn track_free(bytes: usize) {
    CURRENT.fetch_sub(bytes, ORD);
}

/// Currently tracked overhead bytes.
pub fn current_bytes() -> usize {
    CURRENT.load(ORD)
}

/// All-time peak of tracked overhead bytes.
pub fn peak_bytes() -> usize {
    PEAK.load(ORD)
}

/// A measurement scope: captures the tracked peak *during* the scope by
/// recording the baseline at `begin()` and watermarking increments above
/// it. Implementation note: the global PEAK is all-time, so the scope
/// resets it down to `current` at begin — safe because scopes are used by
/// single-measurement bench/test code, not concurrently.
pub struct MeasureScope {
    baseline: usize,
}

impl MeasureScope {
    pub fn begin() -> MeasureScope {
        let cur = current_bytes();
        PEAK.store(cur, ORD);
        MeasureScope { baseline: cur }
    }

    /// Peak overhead accumulated since `begin()`, relative to the baseline.
    pub fn peak(&self) -> usize {
        peak_bytes().saturating_sub(self.baseline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let before = current_bytes();
        track_alloc(123);
        assert_eq!(current_bytes(), before + 123);
        track_free(123);
        assert_eq!(current_bytes(), before);
    }

    #[test]
    fn scope_measures_relative_peak() {
        let scope = MeasureScope::begin();
        track_alloc(1000);
        track_free(1000);
        track_alloc(400);
        track_free(400);
        assert_eq!(scope.peak(), 1000);
    }
}
