//! Shared workspace arena + named workspace layouts — the memory side of
//! the plan/execute split (see `ARCHITECTURE.md`).
//!
//! A [`ConvPlan`](crate::conv::ConvPlan) computes, at plan time, a
//! [`WorkspaceLayout`]: the named scratch regions it will need at every
//! `execute`, as offsets into **one** buffer. The planner then sizes a
//! single [`Arena`] per model at the **max** (not the sum) of the
//! per-layer totals — layers execute sequentially, so they can all share
//! the same bytes. That is exactly the paper's memory-overhead metric
//! (Fig. 4b/4e) applied to a whole network instead of one layer.
//!
//! Like [`Workspace`](super::Workspace), the arena records its growth in
//! the global [`tracker`](super::tracker), so tests and benches can assert
//! the whole-model peak equals the analytic max.

use super::aligned::{AlignedVec, ALIGN};
use super::tracker;

/// One named region inside a workspace buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub name: &'static str,
    /// Offset in floats from the start of the buffer.
    pub offset: usize,
    /// Length in floats.
    pub elems: usize,
}

/// A plan's scratch-memory map: named regions at fixed offsets in a single
/// buffer. Regions are contiguous in declaration order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WorkspaceLayout {
    regions: Vec<Region>,
    total: usize,
}

impl WorkspaceLayout {
    pub fn new() -> WorkspaceLayout {
        WorkspaceLayout::default()
    }

    /// Append a region of `elems` floats; returns its index (stable — the
    /// plan uses it to address the slice returned by [`Self::split`]).
    pub fn push(&mut self, name: &'static str, elems: usize) -> usize {
        let idx = self.regions.len();
        self.regions.push(Region {
            name,
            offset: self.total,
            elems,
        });
        self.total += elems;
        idx
    }

    /// Append a region that stores `elems` **i16** values inside the f32
    /// buffer: two lanes per f32 slot, rounded up — how q16 plans get
    /// their halved lowering buffers out of the shared f32 arena
    /// ([`f32_as_i16_mut`](crate::tensor::quant::f32_as_i16_mut)
    /// reinterprets the slice at execute time). Returns the region index;
    /// the recorded `elems` is in f32 slots like every other region, so
    /// arena sizing and the max-over-layers rule need no special cases.
    pub fn push_i16(&mut self, name: &'static str, i16_elems: usize) -> usize {
        self.push(name, i16_elems.div_ceil(2))
    }

    /// Total floats across all regions — the plan's workspace requirement.
    pub fn total_elems(&self) -> usize {
        self.total
    }

    /// Total bytes.
    pub fn total_bytes(&self) -> usize {
        self.total * std::mem::size_of::<f32>()
    }

    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    /// Look up a region by name (diagnostics / tests).
    pub fn region(&self, name: &str) -> Option<&Region> {
        self.regions.iter().find(|r| r.name == name)
    }

    /// Split a scratch buffer into the per-region slices, in declaration
    /// order. `buf` must hold at least [`Self::total_elems`] floats.
    pub fn split<'a>(&self, buf: &'a mut [f32]) -> Vec<&'a mut [f32]> {
        assert!(
            buf.len() >= self.total,
            "workspace buffer {} floats < layout total {}",
            buf.len(),
            self.total
        );
        let mut out = Vec::with_capacity(self.regions.len());
        let mut rest = buf;
        for r in &self.regions {
            let (head, tail) = rest.split_at_mut(r.elems);
            out.push(head);
            rest = tail;
        }
        out
    }
}

/// A tracked, growable scratch buffer shared by every planned layer of a
/// model. Sized once (high-water) by the planner; the serving hot path
/// never grows it. Growth and release are recorded in the global tracker.
/// Storage is 64-byte aligned ([`AlignedVec`]) for the SIMD micro-kernels.
#[derive(Debug, Default)]
pub struct Arena {
    buf: AlignedVec<f32>,
}

impl Arena {
    /// Empty arena (no tracked bytes).
    pub fn new() -> Arena {
        Arena {
            buf: AlignedVec::new(),
        }
    }

    /// Arena pre-sized to `elems` floats (the planner's sizing path).
    pub fn with_capacity(elems: usize) -> Arena {
        let mut a = Arena::new();
        a.reserve(elems);
        a
    }

    /// Ensure capacity for `elems` floats, growing (and recording) if
    /// needed. Never shrinks.
    pub fn reserve(&mut self, elems: usize) {
        if elems > self.buf.len() {
            let grow = elems - self.buf.len();
            tracker::track_alloc(grow * 4);
            self.buf.resize(elems, 0.0);
        }
        debug_assert!(
            self.buf.is_empty() || self.buf.as_ptr() as usize % ALIGN == 0,
            "Arena buffer lost {ALIGN}-byte alignment"
        );
    }

    /// Fallible [`reserve`](Self::reserve): a refused growth (real, or
    /// injected at the `memory.arena.grow` fault site) comes back as a
    /// typed [`AllocError`](super::AllocError) with the arena unchanged,
    /// so the engine can degrade the plan instead of aborting. A
    /// zero-element request never fails: the zero-workspace algorithm
    /// family is immune by construction, which is what makes it the
    /// bottom rung of the degradation ladder.
    pub fn try_reserve(&mut self, elems: usize) -> Result<(), super::AllocError> {
        if elems > 0 && crate::faultpoint!(alloc "memory.arena.grow") {
            return Err(super::AllocError {
                bytes: elems.saturating_sub(self.buf.len()) * 4,
                site: "memory.arena.grow",
            });
        }
        if elems > self.buf.len() {
            let grow = elems - self.buf.len();
            self.buf.try_resize(elems, 0.0).map_err(|e| super::AllocError {
                site: "memory.arena.grow",
                ..e
            })?;
            tracker::track_alloc(grow * 4);
        }
        Ok(())
    }

    /// Borrow the first `elems` floats. Contents are stale (whatever the
    /// previous frame left) — plans fully overwrite what they read, which
    /// is why this is not zero-filled. Debug builds poison the slice with
    /// [`POISON_BITS`](super::POISON_BITS) NaNs so a plan that reads a
    /// region before writing it fails loudly in the correctness suites.
    pub fn slice(&mut self, elems: usize) -> &mut [f32] {
        self.reserve(elems);
        let s = &mut self.buf[..elems];
        super::poison_fill(s);
        s
    }

    /// Current capacity in floats.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Current capacity in bytes — the arena's tracked footprint.
    pub fn bytes(&self) -> usize {
        self.buf.len() * 4
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        tracker::track_free(self.buf.len() * 4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::current_bytes;

    #[test]
    fn layout_offsets_are_contiguous() {
        let mut l = WorkspaceLayout::new();
        let a = l.push("lowered", 10);
        let b = l.push("aux", 5);
        assert_eq!((a, b), (0, 1));
        assert_eq!(l.total_elems(), 15);
        assert_eq!(l.total_bytes(), 60);
        assert_eq!(l.region("aux").unwrap().offset, 10);
        assert!(l.region("nope").is_none());
    }

    #[test]
    fn push_i16_packs_two_lanes_per_slot() {
        let mut l = WorkspaceLayout::new();
        l.push_i16("q-lowered", 10); // 5 f32 slots
        l.push_i16("q-odd", 7); // 4 f32 slots (rounded up)
        l.push("aux", 3);
        assert_eq!(l.region("q-lowered").unwrap().elems, 5);
        assert_eq!(l.region("q-odd").unwrap().elems, 4);
        assert_eq!(l.region("aux").unwrap().offset, 9);
        assert_eq!(l.total_elems(), 12);
    }

    #[test]
    fn layout_split_is_disjoint_and_ordered() {
        let mut l = WorkspaceLayout::new();
        l.push("a", 3);
        l.push("b", 2);
        let mut buf = vec![0.0f32; 6]; // one spare float beyond the layout
        let parts = l.split(&mut buf);
        assert_eq!(parts.len(), 2);
        assert_eq!((parts[0].len(), parts[1].len()), (3, 2));
        parts.into_iter().flatten().for_each(|v| *v = 1.0);
        assert_eq!(buf[..5], [1.0; 5]);
        assert_eq!(buf[5], 0.0);
    }

    #[test]
    fn empty_layout_splits_to_nothing() {
        let l = WorkspaceLayout::new();
        let mut buf: Vec<f32> = Vec::new();
        assert!(l.split(&mut buf).is_empty());
        assert_eq!(l.total_elems(), 0);
    }

    #[test]
    fn arena_tracks_growth_and_release() {
        let before = current_bytes();
        {
            let mut a = Arena::with_capacity(100);
            assert_eq!(current_bytes(), before + 400);
            let _ = a.slice(50); // no growth
            assert_eq!(current_bytes(), before + 400);
            a.reserve(200); // grows by 100 floats
            assert_eq!(current_bytes(), before + 800);
            assert_eq!(a.capacity(), 200);
            assert_eq!(a.bytes(), 800);
        }
        assert_eq!(current_bytes(), before);
    }

    #[test]
    fn arena_slice_is_stale_in_release_and_poisoned_in_debug() {
        let mut a = Arena::new();
        a.slice(4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let s = a.slice(4);
        if cfg!(debug_assertions) {
            // Debug builds poison fresh borrows so read-before-write
            // plans surface as NaNs instead of silently reusing frames.
            assert!(
                s.iter().all(|v| v.to_bits() == crate::memory::POISON_BITS),
                "Arena::slice must poison in debug builds, got {s:?}"
            );
        } else {
            // Release: not zeroed on re-borrow — plans rely on overwrite
            // semantics and the borrow stays zero-cost.
            assert_eq!(s, &[1.0, 2.0, 3.0, 4.0]);
        }
    }
}
