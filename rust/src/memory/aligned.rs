//! 64-byte-aligned growable buffers for arena and packed-operand storage.
//!
//! The SIMD micro-kernels (`gemm::micro`) stream packed A/B strips with
//! 256/512-bit loads; cache-line alignment keeps every vector load inside
//! one line and makes the strips friendly to whatever wider ISA the
//! dispatcher picked. `Vec<f32>` only guarantees 4-byte alignment, so the
//! arena, workspace, and packed buffers use [`AlignedVec`] instead — a
//! minimal `Vec` replacement (length + capacity + geometric `resize`)
//! whose allocation is always [`ALIGN`]-byte aligned.
//!
//! Restricted to `T: Copy` element types (`f32`, `i16`): no drop glue, so
//! truncation and reallocation are plain memcpys.

use super::AllocError;
use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Allocation alignment: one x86 cache line, and ≥ the widest vector
/// (64 B = one AVX-512 zmm row).
pub const ALIGN: usize = 64;

/// A growable, always-[`ALIGN`]-aligned buffer of plain-old-data.
///
/// Supports exactly what the memory layer needs — `resize`, slice
/// access via `Deref`, `Clone` — and nothing else. Capacity never
/// shrinks; `resize` down is a length change only (same contract the
/// arena relied on with `Vec`).
pub struct AlignedVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
}

// SAFETY: AlignedVec owns its allocation exclusively (no interior
// sharing), so it is Send/Sync exactly when the element type is.
unsafe impl<T: Copy + Send> Send for AlignedVec<T> {}
unsafe impl<T: Copy + Sync> Sync for AlignedVec<T> {}

impl<T: Copy> AlignedVec<T> {
    /// An empty buffer; does not allocate.
    pub const fn new() -> AlignedVec<T> {
        AlignedVec {
            ptr: NonNull::dangling(),
            len: 0,
            cap: 0,
        }
    }

    /// A `value`-filled buffer of `len` elements (the `vec![v; n]`
    /// shape).
    pub fn filled(len: usize, value: T) -> AlignedVec<T> {
        let mut v = AlignedVec::new();
        v.resize(len, value);
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn layout(cap: usize) -> Layout {
        // 64 exceeds align_of::<T>() for every element type the crate
        // stores (f32/i16/i32); Layout checks size overflow for us.
        Layout::from_size_align(cap * std::mem::size_of::<T>(), ALIGN.max(std::mem::align_of::<T>()))
            .expect("AlignedVec: layout overflow")
    }

    /// Grow the allocation to hold at least `needed` elements, copying
    /// the live prefix. Geometric growth so repeated small `resize`s
    /// stay amortized-O(1), like `Vec`. Aborts on allocation failure
    /// (the infallible path); [`AlignedVec::try_grow`] is the fallible
    /// twin.
    fn grow(&mut self, needed: usize) {
        if self.try_grow(needed).is_err() {
            handle_alloc_error(Self::layout(needed.max(self.cap * 2).max(8)));
        }
    }

    /// Fallible [`grow`](Self::grow): identical growth recipe, but a
    /// refused allocation comes back as a typed [`AllocError`] with the
    /// vector untouched, instead of aborting the process.
    pub fn try_grow(&mut self, needed: usize) -> Result<(), AllocError> {
        let new_cap = needed.max(self.cap * 2).max(8);
        let layout = Self::layout(new_cap);
        // SAFETY: layout has non-zero size — new_cap >= 8 and
        // `resize`/`try_resize` short-circuit zero-sized element types
        // before calling grow.
        let new_ptr = unsafe { alloc(layout) as *mut T };
        let Some(new_nn) = NonNull::new(new_ptr) else {
            return Err(AllocError { bytes: layout.size(), site: "memory.aligned.alloc" });
        };
        if self.cap > 0 {
            // SAFETY: both regions are valid for `self.len` elements and
            // distinct allocations.
            unsafe {
                std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), new_nn.as_ptr(), self.len);
                dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap));
            }
        }
        self.ptr = new_nn;
        self.cap = new_cap;
        Ok(())
    }

    /// Fallible [`resize`](Self::resize): on `Err` the vector is
    /// unchanged (length and contents intact).
    pub fn try_resize(&mut self, new_len: usize, value: T) -> Result<(), AllocError> {
        if std::mem::size_of::<T>() > 0 && new_len > self.cap {
            self.try_grow(new_len)?;
        }
        self.resize(new_len, value);
        Ok(())
    }

    /// Set the length to `new_len`, filling any newly exposed tail with
    /// `value`. Never shrinks capacity.
    pub fn resize(&mut self, new_len: usize, value: T) {
        if std::mem::size_of::<T>() == 0 {
            self.len = new_len;
            return;
        }
        if new_len > self.cap {
            self.grow(new_len);
        }
        if new_len > self.len {
            // SAFETY: capacity covers new_len; the tail is owned,
            // uninitialized-or-stale POD memory.
            unsafe {
                let base = self.ptr.as_ptr();
                for i in self.len..new_len {
                    base.add(i).write(value);
                }
            }
        }
        self.len = new_len;
        debug_assert!(
            self.cap == 0 || (self.ptr.as_ptr() as usize) % ALIGN == 0,
            "AlignedVec: allocation lost {ALIGN}-byte alignment"
        );
    }

    /// Drop all elements (length 0; capacity retained).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

impl<T: Copy> Drop for AlignedVec<T> {
    fn drop(&mut self) {
        if self.cap > 0 && std::mem::size_of::<T>() > 0 {
            // SAFETY: allocated in grow() with the same layout recipe.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) }
        }
    }
}

impl<T: Copy> Deref for AlignedVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // SAFETY: ptr is valid for len initialized elements (dangling
        // only when len == 0, where a zero-length slice is fine).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> DerefMut for AlignedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as Deref, plus exclusive access through &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> Clone for AlignedVec<T> {
    fn clone(&self) -> AlignedVec<T> {
        let mut v = AlignedVec::new();
        if self.len > 0 {
            v.grow(self.len);
            // SAFETY: both buffers hold at least len elements.
            unsafe {
                std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), v.ptr.as_ptr(), self.len);
            }
            v.len = self.len;
        }
        v
    }
}

impl<T: Copy> Default for AlignedVec<T> {
    fn default() -> Self {
        AlignedVec::new()
    }
}

impl<T: Copy + fmt::Debug> fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_does_not_allocate_and_derefs_to_empty_slice() {
        let v: AlignedVec<f32> = AlignedVec::new();
        assert!(v.is_empty());
        assert_eq!(v.len(), 0);
        assert_eq!(&v[..], &[] as &[f32]);
    }

    #[test]
    fn resize_fills_grows_and_is_cacheline_aligned() {
        let mut v: AlignedVec<f32> = AlignedVec::new();
        v.resize(5, 1.5);
        assert_eq!(&v[..], &[1.5; 5]);
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        // Grow across several reallocations; prefix survives.
        v[0] = -2.0;
        v.resize(1000, 0.25);
        assert_eq!(v[0], -2.0);
        assert_eq!(v[1], 1.5);
        assert_eq!(v[999], 0.25);
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        // Shrink is a length change; regrow re-exposes filled values.
        v.resize(2, 9.0);
        assert_eq!(v.len(), 2);
        v.resize(3, 7.0);
        assert_eq!(&v[..], &[-2.0, 1.5, 7.0]);
    }

    #[test]
    fn i16_storage_aligns_too() {
        let mut v: AlignedVec<i16> = AlignedVec::filled(77, -3);
        assert_eq!(v.len(), 77);
        assert!(v.iter().all(|&x| x == -3));
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        v.clear();
        assert!(v.is_empty());
        v.resize(4, 2);
        assert_eq!(&v[..], &[2, 2, 2, 2]);
    }

    #[test]
    fn clone_copies_contents_into_a_fresh_aligned_allocation() {
        let mut v: AlignedVec<f32> = AlignedVec::new();
        v.resize(9, 3.0);
        v[4] = -1.0;
        let w = v.clone();
        assert_eq!(&w[..], &v[..]);
        assert_eq!(w.as_ptr() as usize % ALIGN, 0);
        assert_ne!(w.as_ptr(), v.as_ptr());
        let empty: AlignedVec<f32> = AlignedVec::new();
        assert!(empty.clone().is_empty());
    }
}
