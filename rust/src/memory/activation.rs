//! [`ActivationArena`] — the activation side of the shared-memory story.
//!
//! The workspace [`Arena`](super::Arena) gives every planned conv layer
//! one reusable scratch buffer sized at the max over layers. Activations
//! get the same treatment from the graph IR's liveness pass
//! (`model::graph_ir`): every intermediate value is assigned a **slot**
//! by interval coloring, so the arena holds max-live-set bytes — not the
//! sum of node outputs — and the serving hot path performs zero tracked
//! allocation once a batch size has been seen.
//!
//! Slots are `Vec<f32>` buffers so the executor can move them into
//! [`Tensor`](crate::tensor::Tensor)s and back without copying (the conv
//! plans execute on tensors, not raw slices). Growth is recorded in the
//! global [`tracker`](super::tracker), exactly like the workspace arena,
//! so tests can assert the measured activation peak equals the liveness
//! plan's analytic figure.

use super::tracker;

/// A tracked set of reusable activation slots, owned by whoever runs
/// forwards (a `Session`, an executor, a test). Capacity only grows.
#[derive(Debug, Default)]
pub struct ActivationArena {
    slots: Vec<Vec<f32>>,
    /// Tracked capacity (floats) per slot — kept outside the Vecs so a
    /// taken (empty) slot still accounts for its buffer.
    caps: Vec<usize>,
}

impl ActivationArena {
    /// Empty arena (no tracked bytes).
    pub fn new() -> ActivationArena {
        ActivationArena::default()
    }

    /// Arena pre-sized to the per-slot float counts `elems` (what an
    /// engine sizes sessions with at build time).
    pub fn with_slots(elems: &[usize]) -> ActivationArena {
        let mut a = ActivationArena::new();
        for (i, &e) in elems.iter().enumerate() {
            a.ensure(i, e);
        }
        a
    }

    /// Number of slots seen so far.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Ensure slot `slot` exists with capacity for `elems` floats,
    /// growing (and recording) if needed. Never shrinks.
    pub fn ensure(&mut self, slot: usize, elems: usize) {
        while self.slots.len() <= slot {
            self.slots.push(Vec::new());
            self.caps.push(0);
        }
        if elems > self.caps[slot] {
            let grow = elems - self.caps[slot];
            tracker::track_alloc(grow * 4);
            self.slots[slot].reserve_exact(elems - self.slots[slot].len());
            self.caps[slot] = elems;
        }
    }

    /// Move slot `slot`'s buffer out (zero-copy). Must be paired with
    /// [`ActivationArena::put`]; the slot accounts for its capacity even
    /// while taken.
    pub fn take(&mut self, slot: usize) -> Vec<f32> {
        std::mem::take(&mut self.slots[slot])
    }

    /// Return a buffer taken from `slot`. If an op grew it beyond the
    /// reserved capacity (it should not), the growth is recorded.
    pub fn put(&mut self, slot: usize, buf: Vec<f32>) {
        if buf.capacity() > self.caps[slot] {
            tracker::track_alloc((buf.capacity() - self.caps[slot]) * 4);
            self.caps[slot] = buf.capacity();
        }
        self.slots[slot] = buf;
    }

    /// Read-only view of a slot's current contents.
    pub fn data(&self, slot: usize) -> &[f32] {
        &self.slots[slot]
    }

    /// Tracked footprint in bytes (Σ slot capacities) — the quantity the
    /// arena-peak tests compare to the liveness plan's max live set.
    pub fn bytes(&self) -> usize {
        self.caps.iter().sum::<usize>() * 4
    }
}

impl Drop for ActivationArena {
    fn drop(&mut self) {
        tracker::track_free(self.caps.iter().sum::<usize>() * 4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::current_bytes;

    #[test]
    fn tracks_growth_take_put_and_release() {
        let before = current_bytes();
        {
            let mut a = ActivationArena::new();
            a.ensure(0, 10);
            a.ensure(1, 5);
            assert_eq!(a.bytes(), 60);
            assert_eq!(current_bytes(), before + 60);
            a.ensure(0, 8); // never shrinks
            assert_eq!(a.bytes(), 60);
            let mut v = a.take(0);
            assert_eq!(current_bytes(), before + 60, "taken slot still tracked");
            v.resize(10, 1.0);
            a.put(0, v);
            assert_eq!(a.data(0), &[1.0; 10]);
            assert_eq!(a.bytes(), 60);
        }
        assert_eq!(current_bytes(), before, "drop releases tracked bytes");
    }

    #[test]
    fn with_slots_presizes() {
        let a = ActivationArena::with_slots(&[4, 0, 2]);
        assert_eq!(a.slot_count(), 3);
        assert_eq!(a.bytes(), 24);
    }
}
