//! [`ActivationArena`] — the activation side of the shared-memory story.
//!
//! The workspace [`Arena`](super::Arena) gives every planned conv layer
//! one reusable scratch buffer sized at the max over layers. Activations
//! get the same treatment from the graph IR's liveness pass
//! (`model::graph_ir`): every intermediate value is assigned a **slot**
//! by interval coloring, so the arena holds max-live-set bytes — not the
//! sum of node outputs — and the serving hot path performs zero tracked
//! allocation once a batch size has been seen.
//!
//! Slots are `Vec<f32>` buffers so the executor can move them into
//! [`Tensor`](crate::tensor::Tensor)s and back without copying (the conv
//! plans execute on tensors, not raw slices). Growth is recorded in the
//! global [`tracker`](super::tracker), exactly like the workspace arena,
//! so tests can assert the measured activation peak equals the liveness
//! plan's analytic figure.

use super::tracker;

/// A tracked set of reusable activation slots, owned by whoever runs
/// forwards (a `Session`, an executor, a test). Capacity only grows.
///
/// Debug builds add two misuse guards (both compile out in release):
/// * newly grown slot storage is exposed as
///   [`POISON_BITS`](super::POISON_BITS) NaNs, so an op that reads a
///   fresh slot before writing it fails loudly in the numerics suites;
/// * [`take`](ActivationArena::take)/[`put`](ActivationArena::put)
///   pairing is asserted per slot — double-takes and unmatched puts are
///   exactly the bugs that silently alias two live activations.
#[derive(Debug, Default)]
pub struct ActivationArena {
    slots: Vec<Vec<f32>>,
    /// Tracked capacity (floats) per slot — kept outside the Vecs so a
    /// taken (empty) slot still accounts for its buffer.
    caps: Vec<usize>,
    /// Debug-only: which slots are currently taken.
    #[cfg(debug_assertions)]
    taken: Vec<bool>,
}

impl ActivationArena {
    /// Empty arena (no tracked bytes).
    pub fn new() -> ActivationArena {
        ActivationArena::default()
    }

    /// Arena pre-sized to the per-slot float counts `elems` (what an
    /// engine sizes sessions with at build time).
    pub fn with_slots(elems: &[usize]) -> ActivationArena {
        let mut a = ActivationArena::new();
        for (i, &e) in elems.iter().enumerate() {
            a.ensure(i, e);
        }
        a
    }

    /// Number of slots seen so far.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Ensure slot `slot` exists with capacity for `elems` floats,
    /// growing (and recording) if needed. Never shrinks.
    pub fn ensure(&mut self, slot: usize, elems: usize) {
        while self.slots.len() <= slot {
            self.slots.push(Vec::new());
            self.caps.push(0);
            #[cfg(debug_assertions)]
            self.taken.push(false);
        }
        if elems > self.caps[slot] {
            let grow = elems - self.caps[slot];
            tracker::track_alloc(grow * 4);
            self.slots[slot].reserve_exact(elems - self.slots[slot].len());
            self.caps[slot] = elems;
            // Debug canary: expose the newly grown tail as poison NaNs
            // (live contents below the old length are preserved). The
            // executor resizes after `take`, so the release build never
            // sees this length change.
            #[cfg(debug_assertions)]
            {
                debug_assert!(
                    !self.taken[slot],
                    "ActivationArena::ensure({slot}): slot is currently taken"
                );
                self.slots[slot].resize(elems, super::poison());
            }
        }
    }

    /// Fallible [`ensure`](Self::ensure): a refused growth (real, or
    /// injected at the `memory.activation.grow` fault site) comes back
    /// as a typed [`AllocError`](super::AllocError) with the arena
    /// unchanged. Unlike workspace, activation demand does not shrink
    /// under plan degradation — a refusal here fails the one request,
    /// typed, at the session boundary.
    pub fn try_ensure(&mut self, slot: usize, elems: usize) -> Result<(), super::AllocError> {
        if elems > 0 && crate::faultpoint!(alloc "memory.activation.grow") {
            return Err(super::AllocError {
                bytes: elems.saturating_sub(self.caps.get(slot).copied().unwrap_or(0)) * 4,
                site: "memory.activation.grow",
            });
        }
        while self.slots.len() <= slot {
            self.slots.push(Vec::new());
            self.caps.push(0);
            #[cfg(debug_assertions)]
            self.taken.push(false);
        }
        if elems > self.caps[slot] {
            let grow = elems - self.caps[slot];
            let want = elems - self.slots[slot].len();
            if self.slots[slot].try_reserve_exact(want).is_err() {
                return Err(super::AllocError {
                    bytes: grow * 4,
                    site: "memory.activation.grow",
                });
            }
            tracker::track_alloc(grow * 4);
            self.caps[slot] = elems;
            #[cfg(debug_assertions)]
            {
                debug_assert!(
                    !self.taken[slot],
                    "ActivationArena::try_ensure({slot}): slot is currently taken"
                );
                self.slots[slot].resize(elems, super::poison());
            }
        }
        Ok(())
    }

    /// Move slot `slot`'s buffer out (zero-copy). Must be paired with
    /// [`ActivationArena::put`]; the slot accounts for its capacity even
    /// while taken. Debug builds panic on a double-take — the symptom of
    /// two live values coloured into one slot.
    pub fn take(&mut self, slot: usize) -> Vec<f32> {
        #[cfg(debug_assertions)]
        {
            assert!(
                !self.taken[slot],
                "ActivationArena::take({slot}): slot already taken (missing put?)"
            );
            self.taken[slot] = true;
        }
        std::mem::take(&mut self.slots[slot])
    }

    /// Return a buffer taken from `slot`. If an op grew it beyond the
    /// reserved capacity (it should not), the growth is recorded. Debug
    /// builds panic when the slot was not taken — an unmatched `put`
    /// overwrites a buffer some other owner may still expect to hold.
    pub fn put(&mut self, slot: usize, buf: Vec<f32>) {
        #[cfg(debug_assertions)]
        {
            assert!(
                self.taken[slot],
                "ActivationArena::put({slot}): slot was not taken"
            );
            self.taken[slot] = false;
        }
        if buf.capacity() > self.caps[slot] {
            tracker::track_alloc((buf.capacity() - self.caps[slot]) * 4);
            self.caps[slot] = buf.capacity();
        }
        self.slots[slot] = buf;
    }

    /// Read-only view of a slot's current contents.
    pub fn data(&self, slot: usize) -> &[f32] {
        &self.slots[slot]
    }

    /// Tracked footprint in bytes (Σ slot capacities) — the quantity the
    /// arena-peak tests compare to the liveness plan's max live set.
    pub fn bytes(&self) -> usize {
        self.caps.iter().sum::<usize>() * 4
    }
}

impl Drop for ActivationArena {
    fn drop(&mut self) {
        tracker::track_free(self.caps.iter().sum::<usize>() * 4);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::current_bytes;

    #[test]
    fn tracks_growth_take_put_and_release() {
        let before = current_bytes();
        {
            let mut a = ActivationArena::new();
            a.ensure(0, 10);
            a.ensure(1, 5);
            assert_eq!(a.bytes(), 60);
            assert_eq!(current_bytes(), before + 60);
            a.ensure(0, 8); // never shrinks
            assert_eq!(a.bytes(), 60);
            let mut v = a.take(0);
            assert_eq!(current_bytes(), before + 60, "taken slot still tracked");
            v.clear();
            v.resize(10, 1.0);
            a.put(0, v);
            assert_eq!(a.data(0), &[1.0; 10]);
            assert_eq!(a.bytes(), 60);
        }
        assert_eq!(current_bytes(), before, "drop releases tracked bytes");
    }

    #[test]
    fn with_slots_presizes() {
        let a = ActivationArena::with_slots(&[4, 0, 2]);
        assert_eq!(a.slot_count(), 3);
        assert_eq!(a.bytes(), 24);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn ensure_growth_is_poisoned_in_debug() {
        let mut a = ActivationArena::new();
        a.ensure(0, 3);
        assert!(
            a.data(0)
                .iter()
                .all(|v| v.to_bits() == crate::memory::POISON_BITS),
            "fresh slot storage must carry the poison canary"
        );
        // Live contents below the old length survive growth; only the
        // newly exposed tail is poisoned.
        let mut v = a.take(0);
        v.fill(2.0);
        a.put(0, v);
        a.ensure(0, 5);
        assert_eq!(&a.data(0)[..3], &[2.0; 3]);
        assert!(a.data(0)[3..]
            .iter()
            .all(|v| v.to_bits() == crate::memory::POISON_BITS));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "already taken")]
    fn double_take_panics_in_debug() {
        let mut a = ActivationArena::with_slots(&[4]);
        let _v = a.take(0);
        let _w = a.take(0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "was not taken")]
    fn unmatched_put_panics_in_debug() {
        let mut a = ActivationArena::with_slots(&[4]);
        a.put(0, vec![0.0; 4]);
    }
}
