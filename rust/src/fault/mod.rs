//! Deterministic, seeded fault injection — the test harness for every
//! recovery path in the crate.
//!
//! Production code is sprinkled with *named fault sites* (the
//! [`faultpoint!`](crate::faultpoint) macro): places where an allocation
//! may be made to fail, a forward pass made to panic, or a compute step
//! artificially delayed. With no plan armed the check is a single
//! relaxed atomic load — the hot path pays nothing measurable.
//!
//! A plan arms from the environment
//! (`MEC_FAULTS=<seed>:<site>=<action>[@<prob>][#<limit>][,…]`) or from
//! a [`ScopedFaults`] guard in tests. Every probabilistic decision
//! draws from a [`SplitMix64`](crate::util::Rng) stream derived from
//! `seed ^ fnv1a(site)`, so a failing run replays bit-for-bit from the
//! one-line spec — the same discipline as `MEC_FUZZ_SEED` in the
//! differential oracle.
//!
//! Spec grammar (comma-separated clauses):
//!
//! ```text
//! <site>=<action>[@<prob>][#<limit>]
//!   site    exact site name, or a prefix ending in `*`
//!   action  alloc         fail the allocation (typed AllocError)
//!           panic         panic at the site
//!           delay<ms>     sleep <ms> milliseconds at the site
//!   @<prob> firing probability in [0,1] (default 1)
//!   #<limit> max number of firings (default unlimited)
//! ```
//!
//! Example: `MEC_FAULTS=0xbad5eed:memory.arena.grow=alloc#1,engine.forward=panic@0.01`
//!
//! This module is policy only and contains no `unsafe`.

#![forbid(unsafe_code)]

use crate::util::Rng;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, RwLock};

/// What a firing fault site does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Report allocation failure (the site returns a typed `AllocError`).
    FailAlloc,
    /// Panic at the site.
    Panic,
    /// Sleep this many milliseconds at the site.
    DelayMs(u64),
}

/// One armed clause of a fault plan.
#[derive(Debug)]
struct SiteSpec {
    /// Site name; a trailing `*` makes it a prefix match.
    site: String,
    action: FaultAction,
    prob: f64,
    limit: Option<u64>,
    fired: AtomicU64,
    rng: Mutex<Rng>,
}

impl SiteSpec {
    fn matches(&self, site: &str) -> bool {
        match self.site.strip_suffix('*') {
            Some(prefix) => site.starts_with(prefix),
            None => self.site == site,
        }
    }

    /// Decide (deterministically) whether this clause fires now.
    fn fire(&self) -> bool {
        if let Some(limit) = self.limit {
            if self.fired.load(Ordering::Relaxed) >= limit {
                return false;
            }
        }
        let hit = self.prob >= 1.0 || lock_ignore_poison(&self.rng).bool(self.prob);
        if !hit {
            return false;
        }
        if let Some(limit) = self.limit {
            // Claim a firing slot; back off if a racing thread took the last.
            if self.fired.fetch_add(1, Ordering::Relaxed) >= limit {
                return false;
            }
        } else {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        true
    }
}

/// A parsed, armed fault plan.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: String,
    sites: Vec<SiteSpec>,
}

/// Typed parse failure for a `MEC_FAULTS` spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseFaultsError(pub String);

impl std::fmt::Display for ParseFaultsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid MEC_FAULTS spec: {} (expected <seed>:<site>=<action>[@<prob>][#<limit>],…)",
            self.0
        )
    }
}

impl std::error::Error for ParseFaultsError {}

impl FaultPlan {
    /// Parse the full `<seed>:<spec>` form (the `MEC_FAULTS` value).
    pub fn parse(value: &str) -> Result<FaultPlan, ParseFaultsError> {
        let (seed_s, spec) = value
            .split_once(':')
            .ok_or_else(|| ParseFaultsError(format!("{value:?}: missing `seed:` prefix")))?;
        let seed_t = seed_s.trim();
        let seed = match seed_t.strip_prefix("0x").or_else(|| seed_t.strip_prefix("0X")) {
            Some(hex) => u64::from_str_radix(hex, 16),
            None => seed_t.parse::<u64>(),
        }
        .map_err(|_| ParseFaultsError(format!("{value:?}: bad seed {seed_t:?}")))?;
        FaultPlan::from_spec(seed, spec)
    }

    /// Build a plan from a seed and the clause list (no `seed:` prefix).
    pub fn from_spec(seed: u64, spec: &str) -> Result<FaultPlan, ParseFaultsError> {
        let mut sites = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (site, rest) = clause
                .split_once('=')
                .ok_or_else(|| ParseFaultsError(format!("{clause:?}: missing `=`")))?;
            let site = site.trim();
            if site.is_empty() {
                return Err(ParseFaultsError(format!("{clause:?}: empty site")));
            }
            let (rest, limit) = match rest.split_once('#') {
                Some((r, l)) => {
                    let n = l.trim().parse::<u64>().map_err(|_| {
                        ParseFaultsError(format!("{clause:?}: bad limit {l:?}"))
                    })?;
                    (r, Some(n))
                }
                None => (rest, None),
            };
            let (action_s, prob) = match rest.split_once('@') {
                Some((a, p)) => {
                    let p = p.trim().parse::<f64>().ok().filter(|p| (0.0..=1.0).contains(p));
                    match p {
                        Some(p) => (a, p),
                        None => {
                            return Err(ParseFaultsError(format!("{clause:?}: bad probability")))
                        }
                    }
                }
                None => (rest, 1.0),
            };
            let action_s = action_s.trim();
            let action = if action_s == "alloc" {
                FaultAction::FailAlloc
            } else if action_s == "panic" {
                FaultAction::Panic
            } else if let Some(ms) = action_s.strip_prefix("delay") {
                let ms = ms.parse::<u64>().map_err(|_| {
                    ParseFaultsError(format!("{clause:?}: bad delay {ms:?}"))
                })?;
                FaultAction::DelayMs(ms)
            } else {
                return Err(ParseFaultsError(format!(
                    "{clause:?}: unknown action {action_s:?}"
                )));
            };
            sites.push(SiteSpec {
                site: site.to_string(),
                action,
                prob,
                limit,
                fired: AtomicU64::new(0),
                rng: Mutex::new(Rng::new(seed ^ fnv1a(site))),
            });
        }
        if sites.is_empty() {
            return Err(ParseFaultsError(format!("{spec:?}: no clauses")));
        }
        Ok(FaultPlan { seed, spec: spec.trim().to_string(), sites })
    }

    /// The one-line env setting that replays this exact plan.
    pub fn replay_line(&self) -> String {
        format!("MEC_FAULTS={:#x}:{}", self.seed, self.spec)
    }

    /// Total firings so far across every clause.
    pub fn fired(&self) -> u64 {
        self.sites.iter().map(|s| s.fired.load(Ordering::Relaxed)).sum()
    }
}

/// FNV-1a, so each site gets an independent deterministic stream.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

const STATE_UNKNOWN: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

/// Fast-path switch: faultpoints read this before touching any lock.
static STATE: AtomicU8 = AtomicU8::new(STATE_UNKNOWN);

fn active() -> &'static RwLock<Option<std::sync::Arc<FaultPlan>>> {
    static ACTIVE: OnceLock<RwLock<Option<std::sync::Arc<FaultPlan>>>> = OnceLock::new();
    ACTIVE.get_or_init(|| RwLock::new(None))
}

/// Serializes [`ScopedFaults`] guards so concurrent tests can't fight
/// over the global plan.
fn scope_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Cold path of [`armed`]: parse `MEC_FAULTS` once.
#[cold]
fn init_from_env() -> bool {
    let on = match std::env::var("MEC_FAULTS") {
        Ok(v) if !v.trim().is_empty() => match FaultPlan::parse(&v) {
            Ok(plan) => {
                let mut g = active().write().unwrap_or_else(|p| p.into_inner());
                if g.is_none() {
                    *g = Some(std::sync::Arc::new(plan));
                }
                true
            }
            Err(e) => {
                eprintln!("mec: ignoring {e}");
                false
            }
        },
        _ => false,
    };
    // A racing ScopedFaults install wins: only move UNKNOWN.
    let _ = STATE.compare_exchange(
        STATE_UNKNOWN,
        if on { STATE_ON } else { STATE_OFF },
        Ordering::AcqRel,
        Ordering::Acquire,
    );
    STATE.load(Ordering::Acquire) == STATE_ON
}

/// Is any fault plan armed? One relaxed load when the answer is no.
#[inline]
pub fn armed() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_OFF => false,
        STATE_ON => true,
        _ => init_from_env(),
    }
}

/// The armed plan, if any (for replay lines and firing counts).
pub fn current_plan() -> Option<std::sync::Arc<FaultPlan>> {
    if !armed() {
        return None;
    }
    active().read().unwrap_or_else(|p| p.into_inner()).clone()
}

/// Total fault firings under the armed plan (0 when disarmed).
pub fn fired() -> u64 {
    current_plan().map(|p| p.fired()).unwrap_or(0)
}

fn decide(site: &str) -> Option<FaultAction> {
    let plan = active().read().unwrap_or_else(|p| p.into_inner()).clone()?;
    for spec in &plan.sites {
        if spec.matches(site) && spec.fire() {
            return Some(spec.action);
        }
    }
    None
}

/// Should an allocation at `site` be made to fail? Only `alloc` clauses
/// apply here (a `panic` clause at the same site panics instead, so a
/// mis-targeted spec fails loudly rather than silently doing nothing).
#[inline]
pub fn alloc_should_fail(site: &str) -> bool {
    if !armed() {
        return false;
    }
    match decide(site) {
        Some(FaultAction::FailAlloc) => true,
        Some(FaultAction::Panic) => panic!("mec::fault: injected panic at {site}"),
        Some(FaultAction::DelayMs(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            false
        }
        None => false,
    }
}

/// Panic or delay at `site` if an armed clause says so.
#[inline]
pub fn check(site: &str) {
    if !armed() {
        return;
    }
    match decide(site) {
        Some(FaultAction::Panic) => panic!("mec::fault: injected panic at {site}"),
        Some(FaultAction::DelayMs(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms))
        }
        Some(FaultAction::FailAlloc) | None => {}
    }
}

/// A named fault site.
///
/// * `faultpoint!(alloc "site")` → `bool`: should this allocation fail?
/// * `faultpoint!("site")` → may panic or sleep here, per the armed plan.
///
/// Both forms compile to a single relaxed atomic load when no plan is
/// armed.
#[macro_export]
macro_rules! faultpoint {
    (alloc $site:expr) => {
        $crate::fault::alloc_should_fail($site)
    };
    ($site:expr) => {
        $crate::fault::check($site)
    };
}

// ---------------------------------------------------------------------
// Panic-layer breadcrumbs: which graph node was executing when a forward
// panicked. The executor wraps each step in a LayerScope; if the step
// unwinds, the scope's Drop records the node index for the
// catch_unwind boundary (coordinator worker) to pick up.
// ---------------------------------------------------------------------

thread_local! {
    static CURRENT_LAYER: Cell<Option<usize>> = const { Cell::new(None) };
    static LAST_PANIC_LAYER: Cell<Option<usize>> = const { Cell::new(None) };
}

/// RAII marker: "layer `idx` is executing on this thread".
pub struct LayerScope {
    prev: Option<usize>,
}

impl LayerScope {
    pub fn enter(idx: usize) -> LayerScope {
        let prev = CURRENT_LAYER.with(|c| c.replace(Some(idx)));
        LayerScope { prev }
    }
}

impl Drop for LayerScope {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Some(idx) = CURRENT_LAYER.with(|c| c.get()) {
                LAST_PANIC_LAYER.with(|c| c.set(Some(idx)));
            }
        }
        CURRENT_LAYER.with(|c| c.set(self.prev));
    }
}

/// The node index recorded by the most recent panicking [`LayerScope`]
/// on this thread, clearing it. `None` if the panic happened outside
/// any layer.
pub fn take_panic_layer() -> Option<usize> {
    LAST_PANIC_LAYER.with(|c| c.take())
}

/// Test guard: arm `plan` globally for the guard's lifetime, restoring
/// the previous state (env plan or disarmed) on drop. Guards serialize
/// on a global lock so `#[test]`s using faults never interleave.
pub struct ScopedFaults {
    prev_plan: Option<std::sync::Arc<FaultPlan>>,
    prev_state: u8,
    _scope: MutexGuard<'static, ()>,
}

impl ScopedFaults {
    /// Arm a parsed plan.
    pub fn install(plan: FaultPlan) -> ScopedFaults {
        let scope = lock_ignore_poison(scope_lock());
        // Resolve the env state first so `prev_state` is never UNKNOWN.
        armed();
        let mut g = active().write().unwrap_or_else(|p| p.into_inner());
        let prev_plan = g.replace(std::sync::Arc::new(plan));
        let prev_state = STATE.swap(STATE_ON, Ordering::AcqRel);
        drop(g);
        ScopedFaults { prev_plan, prev_state, _scope: scope }
    }

    /// Arm `spec` under `seed` (panics on a malformed spec — tests).
    pub fn new(seed: u64, spec: &str) -> ScopedFaults {
        ScopedFaults::install(FaultPlan::from_spec(seed, spec).expect("valid fault spec"))
    }

    /// The plan this guard armed.
    pub fn plan(&self) -> std::sync::Arc<FaultPlan> {
        active()
            .read()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
            .expect("ScopedFaults holds a plan")
    }
}

impl Drop for ScopedFaults {
    fn drop(&mut self) {
        let mut g = active().write().unwrap_or_else(|p| p.into_inner());
        *g = self.prev_plan.take();
        STATE.store(self.prev_state, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse(
            "0xbeef:memory.arena.grow=alloc#1,engine.forward=panic@0.25,serve.dispatch=delay3",
        )
        .unwrap();
        assert_eq!(p.seed, 0xbeef);
        assert_eq!(p.sites.len(), 3);
        assert_eq!(p.sites[0].action, FaultAction::FailAlloc);
        assert_eq!(p.sites[0].limit, Some(1));
        assert_eq!(p.sites[1].action, FaultAction::Panic);
        assert!((p.sites[1].prob - 0.25).abs() < 1e-12);
        assert_eq!(p.sites[2].action, FaultAction::DelayMs(3));
        assert!(p.replay_line().starts_with("MEC_FAULTS=0xbeef:"));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "no-colon",
            "12:",
            "zz:a=alloc",
            "1:a",
            "1:=alloc",
            "1:a=explode",
            "1:a=alloc@7",
            "1:a=alloc#x",
            "1:a=delayy",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn limit_caps_firings() {
        let _g = ScopedFaults::new(7, "memtest.site=alloc#2");
        assert!(alloc_should_fail("memtest.site"));
        assert!(alloc_should_fail("memtest.site"));
        assert!(!alloc_should_fail("memtest.site"));
        assert!(!alloc_should_fail("memtest.other"));
        assert_eq!(fired(), 2);
    }

    #[test]
    fn prefix_wildcard_matches() {
        let _g = ScopedFaults::new(7, "memory.*=alloc");
        assert!(alloc_should_fail("memory.arena.grow"));
        assert!(alloc_should_fail("memory.activation.grow"));
        assert!(!alloc_should_fail("engine.forward"));
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let run = |seed: u64| {
            let _g = ScopedFaults::new(seed, "p.site=alloc@0.5");
            (0..64).map(|_| alloc_should_fail("p.site")).collect::<Vec<_>>()
        };
        let a = run(42);
        let b = run(42);
        let c = run(43);
        assert_eq!(a, b, "same seed, same firing pattern");
        assert_ne!(a, c, "different seed, different pattern");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p=0.5 mixes");
    }

    #[test]
    fn injected_panic_fires_and_disarms_on_drop() {
        {
            let _g = ScopedFaults::new(1, "boom.site=panic#1");
            let err =
                std::panic::catch_unwind(|| check("boom.site")).expect_err("must panic once");
            let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
            assert!(msg.contains("boom.site"), "payload names the site: {msg}");
            check("boom.site"); // limit hit: no second panic
        }
        check("boom.site"); // disarmed: no panic
    }

    #[test]
    fn layer_scope_records_panicking_layer() {
        let err = std::panic::catch_unwind(|| {
            let _l = LayerScope::enter(3);
            panic!("inside layer 3");
        })
        .expect_err("panics");
        drop(err);
        assert_eq!(take_panic_layer(), Some(3));
        assert_eq!(take_panic_layer(), None, "take clears");
        // A clean pass records nothing.
        {
            let _l = LayerScope::enter(9);
        }
        assert_eq!(take_panic_layer(), None);
    }

    #[test]
    fn scoped_faults_nest_and_restore() {
        assert!(!alloc_should_fail("nest.site"));
        {
            let g = ScopedFaults::new(5, "nest.site=alloc");
            assert!(alloc_should_fail("nest.site"));
            assert!(g.plan().fired() >= 1);
        }
        assert!(!alloc_should_fail("nest.site"));
    }
}
