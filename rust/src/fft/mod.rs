//! FFT substrate — our stand-in for cuFFT, used by the FFT-based
//! convolution baseline (paper §2.2, Fig. 4e/f).
//!
//! Iterative radix-2 Cooley–Tukey over `Complex32`, plus 2-D transforms
//! (row FFTs then column FFTs). Sizes are rounded up to powers of two by
//! the caller — exactly the padding that gives FFT-based convolution its
//! notorious memory overhead, which Fig. 4e measures.

use std::f64::consts::PI;

/// Minimal complex number (num-complex is not vendored).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C32 {
    pub re: f32,
    pub im: f32,
}

impl C32 {
    pub const ZERO: C32 = C32 { re: 0.0, im: 0.0 };

    pub fn new(re: f32, im: f32) -> C32 {
        C32 { re, im }
    }

    #[inline(always)]
    pub fn add(self, o: C32) -> C32 {
        C32::new(self.re + o.re, self.im + o.im)
    }

    #[inline(always)]
    pub fn sub(self, o: C32) -> C32 {
        C32::new(self.re - o.re, self.im - o.im)
    }

    #[inline(always)]
    pub fn mul(self, o: C32) -> C32 {
        C32::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    pub fn conj(self) -> C32 {
        C32::new(self.re, -self.im)
    }

    pub fn scale(self, s: f32) -> C32 {
        C32::new(self.re * s, self.im * s)
    }
}

/// Next power of two >= n (n >= 1).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Precomputed twiddle table + bit-reversal permutation for length `n`
/// (power of two). Reused across the many per-channel transforms of one
/// convolution, which matters: twiddle computation is all `sin`/`cos`.
#[derive(Debug, Clone)]
pub struct FftPlan {
    pub n: usize,
    /// twiddles[s] holds the stage-s factors, total n/2 per full table; we
    /// store one flat half-length table: w[j] = exp(-2πi·j/n), j < n/2.
    w: Vec<C32>,
    rev: Vec<u32>,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "FftPlan requires power of two, got {n}");
        let mut w = Vec::with_capacity(n / 2);
        for j in 0..n / 2 {
            let ang = -2.0 * PI * j as f64 / n as f64;
            w.push(C32::new(ang.cos() as f32, ang.sin() as f32));
        }
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| if n > 1 { i.reverse_bits() >> (32 - bits) } else { 0 })
            .collect();
        FftPlan { n, w, rev }
    }

    /// In-place forward FFT of `buf` (length n).
    pub fn forward(&self, buf: &mut [C32]) {
        self.transform(buf, false);
    }

    /// In-place inverse FFT (includes the 1/n normalization).
    pub fn inverse(&self, buf: &mut [C32]) {
        self.transform(buf, true);
        let s = 1.0 / self.n as f32;
        for v in buf.iter_mut() {
            *v = v.scale(s);
        }
    }

    fn transform(&self, buf: &mut [C32], inverse: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        if n == 1 {
            return;
        }
        // Bit-reversal permutation.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        // Butterflies.
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len; // stride into the half-length twiddle table
            let mut start = 0;
            while start < n {
                for k in 0..half {
                    let mut w = self.w[k * step];
                    if inverse {
                        w = w.conj();
                    }
                    let a = buf[start + k];
                    let b = buf[start + k + half].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + half] = a.sub(b);
                }
                start += len;
            }
            len <<= 1;
        }
    }
}

/// 2-D FFT over a row-major `rows × cols` grid (both powers of two),
/// in place: row transforms, then column transforms (via a scratch column).
pub fn fft2d(buf: &mut [C32], rows: usize, cols: usize, inverse: bool) {
    assert_eq!(buf.len(), rows * cols);
    let row_plan = FftPlan::new(cols);
    let col_plan = FftPlan::new(rows);
    for r in 0..rows {
        let row = &mut buf[r * cols..(r + 1) * cols];
        if inverse {
            row_plan.inverse(row);
        } else {
            row_plan.forward(row);
        }
    }
    let mut col = vec![C32::ZERO; rows];
    for c in 0..cols {
        for r in 0..rows {
            col[r] = buf[r * cols + c];
        }
        if inverse {
            col_plan.inverse(&mut col);
        } else {
            col_plan.forward(&mut col);
        }
        for r in 0..rows {
            buf[r * cols + c] = col[r];
        }
    }
}

/// Pointwise `a[i] *= b[i]` over complex spectra — the frequency-domain
/// "multiplication is convolution" step.
pub fn pointwise_mul_acc(acc: &mut [C32], a: &[C32], b: &[C32]) {
    assert_eq!(acc.len(), a.len());
    assert_eq!(a.len(), b.len());
    for i in 0..acc.len() {
        acc[i] = acc[i].add(a[i].mul(b[i]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dft(x: &[C32], inverse: bool) -> Vec<C32> {
        let n = x.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut out = vec![C32::ZERO; n];
        for (k, o) in out.iter_mut().enumerate() {
            let mut s = C32::ZERO;
            for (j, &v) in x.iter().enumerate() {
                let ang = sign * 2.0 * PI * (k * j) as f64 / n as f64;
                s = s.add(v.mul(C32::new(ang.cos() as f32, ang.sin() as f32)));
            }
            *o = if inverse { s.scale(1.0 / n as f32) } else { s };
        }
        out
    }

    fn close(a: &[C32], b: &[C32], tol: f32) -> bool {
        a.iter()
            .zip(b)
            .all(|(x, y)| (x.re - y.re).abs() < tol && (x.im - y.im).abs() < tol)
    }

    #[test]
    fn fft_matches_naive_dft() {
        for n in [1usize, 2, 4, 8, 16, 64] {
            let plan = FftPlan::new(n);
            let mut x: Vec<C32> = (0..n)
                .map(|i| C32::new((i as f32 * 0.7).sin(), (i as f32 * 0.3).cos()))
                .collect();
            let want = naive_dft(&x, false);
            plan.forward(&mut x);
            assert!(close(&x, &want, 1e-3), "n={n}");
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let n = 32;
        let plan = FftPlan::new(n);
        let orig: Vec<C32> = (0..n).map(|i| C32::new(i as f32, -(i as f32) / 3.0)).collect();
        let mut x = orig.clone();
        plan.forward(&mut x);
        plan.inverse(&mut x);
        assert!(close(&x, &orig, 1e-3));
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 64;
        let plan = FftPlan::new(n);
        let x: Vec<C32> = (0..n).map(|i| C32::new((i as f32).sin(), 0.0)).collect();
        let e_time: f64 = x.iter().map(|v| (v.re * v.re + v.im * v.im) as f64).sum();
        let mut f = x.clone();
        plan.forward(&mut f);
        let e_freq: f64 =
            f.iter().map(|v| (v.re * v.re + v.im * v.im) as f64).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() / e_time < 1e-4);
    }

    #[test]
    fn fft2d_roundtrip() {
        let (r, c) = (8, 16);
        let orig: Vec<C32> = (0..r * c).map(|i| C32::new((i % 13) as f32, 0.0)).collect();
        let mut x = orig.clone();
        fft2d(&mut x, r, c, false);
        fft2d(&mut x, r, c, true);
        assert!(close(&x, &orig, 1e-3));
    }

    #[test]
    fn fft_convolution_theorem_1d() {
        // Circular conv of x and h via FFT == naive circular conv.
        let n = 16;
        let plan = FftPlan::new(n);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.9).sin()).collect();
        let h: Vec<f32> = (0..n).map(|i| if i < 3 { 1.0 } else { 0.0 }).collect();
        let mut want = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                want[(i + j) % n] += x[i] * h[j];
            }
        }
        let mut xf: Vec<C32> = x.iter().map(|&v| C32::new(v, 0.0)).collect();
        let mut hf: Vec<C32> = h.iter().map(|&v| C32::new(v, 0.0)).collect();
        plan.forward(&mut xf);
        plan.forward(&mut hf);
        let mut prod = vec![C32::ZERO; n];
        pointwise_mul_acc(&mut prod, &xf, &hf);
        plan.inverse(&mut prod);
        for i in 0..n {
            assert!((prod[i].re - want[i]).abs() < 1e-3, "i={i}");
            assert!(prod[i].im.abs() < 1e-3);
        }
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(7), 8);
        assert_eq!(next_pow2(8), 8);
        assert_eq!(next_pow2(227), 256);
    }
}
