//! # MEC: Memory-efficient Convolution for Deep Neural Network
//!
//! Full-stack reproduction of Cho & Brand, ICML 2017. The library has
//! three layers (see `DESIGN.md`):
//!
//! * **Engine** — every convolution algorithm the paper evaluates, built
//!   from scratch on our own GEMM/FFT/threadpool substrates:
//!   [`conv::direct`], [`conv::im2col`], [`conv::mec`] (the paper's
//!   contribution, Algorithm 2 with Solutions A/B), [`conv::winograd`],
//!   [`conv::fft_conv`]; with exact memory-overhead accounting
//!   ([`memory`]) matching the paper's Eq. (2)/(3)/(4).
//! * **Planner + model** — workspace-budgeted algorithm selection
//!   ([`planner`]), and a graph-IR CNN executor ([`model`]): a DAG of
//!   ops (residual/branching topologies included) compiled through a
//!   pass pipeline — shape inference, conv+bias+relu fusion, dead-node
//!   elimination, and a liveness pass that packs activations into
//!   arena slots at max-live-set footprint — loading weights trained by
//!   the build-time JAX pipeline.
//! * **Coordinator + runtime** — an inference-serving front end
//!   ([`coordinator`]: queue, workers, metrics) scheduled by the
//!   SLO-aware [`serving`] layer (deadline-driven adaptive batching,
//!   admission control with typed load shedding, lock-free latency
//!   histograms, load generators), and a PJRT path ([`runtime`]) that
//!   executes the AOT-lowered JAX/Pallas artifacts through the `xla`
//!   crate.
//!
//! The front door tying the layers together is [`engine`]:
//! [`Engine::builder`] assembles and validates the whole serving
//! configuration (precision, budget, threads, pinned batch sizes,
//! autotune, overrides) into an immutable, `Arc`-shareable [`Engine`];
//! per-thread work goes through [`Engine::session`] → [`Session`].
//!
//! # Unsafe policy
//!
//! `unsafe` is confined to an allowlisted set of leaf modules
//! (threadpool, memory, gemm, the `std::arch` microkernels, the FFT
//! complex reinterpret, and the q16 buffer reinterpret), every block
//! carries a `// SAFETY:` comment, and the in-tree `unsafe-audit` lint
//! (`cargo run -p unsafe-audit`) enforces both. See ARCHITECTURE.md
//! "Unsafe inventory & verification" for which tool (model checker /
//! Miri / sanitizers / audit lint) checks which invariant.

// Every `unsafe` operation inside an `unsafe fn` must be wrapped in its
// own `unsafe {}` block with its own SAFETY justification — a blanket
// "the fn is unsafe" is not an audit trail.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod conv;
pub mod coordinator;
pub mod engine;
pub mod fault;
pub mod fft;
pub mod gemm;
pub mod memory;
pub mod model;
pub mod planner;
pub mod runtime;
pub mod serving;
pub mod tensor;
pub mod threadpool;
pub mod util;

pub use engine::{DegradedLayer, Engine, EngineBuilder, EngineError, Prediction, Session};
pub use tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
