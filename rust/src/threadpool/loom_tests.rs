//! Exhaustive-interleaving tests of the real pool protocol, compiled
//! and run only under `RUSTFLAGS="--cfg loom"` (CI's `loom` job):
//!
//! ```text
//! LOOM_MAX_PREEMPTIONS=2 RUSTFLAGS="--cfg loom" cargo test --lib -- loom
//! ```
//!
//! These drive the exact production `Pool` — dispatch (epoch bump +
//! condvar wake), `fetch_add` slot claiming, the completion-barrier
//! drop guard, and panic propagation — through [`super::model`]'s
//! bounded scheduler. Configurations are deliberately tiny (1–2
//! workers, 2–3 indices): the protocol's states are all reachable at
//! this size, and each extra thread multiplies the schedule space.
//!
//! What each property means when it fails:
//! * an index hit 0 or 2+ times → chunk claiming raced,
//! * a deadlock report → a lost park/unpark wakeup,
//! * a stale read after `run` returns → the completion barrier let the
//!   borrow go before a worker was done,
//! * `catch_unwind` seeing `Ok` → a worker panic was swallowed.

use super::model::model;
use super::sync::atomic::{AtomicUsize, Ordering};
use super::{Pool, SharedSlice};

#[test]
fn loom_every_index_claimed_exactly_once() {
    model(|| {
        let pool = Pool::new(1);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.run(2, 3, &|_slot, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} not claimed exactly once");
        }
    });
}

#[test]
fn loom_slot_ids_stay_under_thread_cap() {
    model(|| {
        let pool = Pool::new(2);
        let bad = AtomicUsize::new(0);
        pool.run(3, 3, &|slot, _i| {
            if slot >= 3 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0, "a worker claimed a slot past the cap");
    });
}

#[test]
fn loom_back_to_back_jobs_never_lose_a_wakeup() {
    // Two dispatches in a row: a worker that parked after (or during)
    // job 1 must observe job 2's epoch bump either on the spin ticker
    // or via the condvar — every schedule must complete, and any lost
    // wakeup surfaces as a deadlock violation.
    model(|| {
        let pool = Pool::new(1);
        let total = AtomicUsize::new(0);
        pool.run(2, 2, &|_, _| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        pool.run(2, 2, &|_, _| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 4);
    });
}

#[test]
fn loom_barrier_releases_borrow_only_after_workers_finish() {
    // Workers write disjoint SharedSlice lanes with plain stores; the
    // submitter reads the buffer immediately after `run` returns. If
    // the completion barrier could release the borrow early in any
    // schedule, some lane would still read 0 here (and the leaked
    // worker would additionally fail the end-of-execution check).
    model(|| {
        let pool = Pool::new(1);
        let mut buf = [0usize; 2];
        {
            let sh = SharedSlice::new(&mut buf);
            pool.run(2, 2, &|_, i| {
                sh.range(i, 1)[0] = i + 1;
            });
        }
        assert_eq!(buf, [1, 2], "disjoint writes must all be visible after the barrier");
    });
}

#[test]
fn loom_worker_panic_is_delivered_to_the_submitter() {
    model(|| {
        let pool = Pool::new(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, 2, &|_, i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "a body panic must re-raise on the submitting thread");
        // The pool must stay usable after a propagated panic.
        let ok = AtomicUsize::new(0);
        pool.run(2, 2, &|_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    });
}

#[test]
fn loom_panicking_job_leaves_the_dispatch_path_pooled() {
    // Panic-then-reuse, across every interleaving: depending on which
    // thread claims chunk 0 first, the panic is raised on the submitter
    // (the `catch_unwind` around its own chunks) or on the worker (the
    // flag re-raised after the barrier) — both re-raise paths must leave
    // the submit mutex released and unpoisoned. The model mutex does not
    // poison, which is exactly why the historical wedge (re-raising
    // while still holding the submit guard, poisoning the std mutex)
    // could never surface here; the dispatch counter closes that gap by
    // asserting the next job is *published*, not merely correct.
    model(|| {
        let pool = Pool::new(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, 2, &|_, i| {
                if i == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "the panic must propagate exactly once");
        let before = pool.jobs_dispatched();
        let ok = AtomicUsize::new(0);
        pool.run(2, 2, &|_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
        assert_eq!(
            pool.jobs_dispatched(),
            before + 1,
            "the job after a panic must publish to the workers, not run inline"
        );
    });
}

#[test]
fn loom_shutdown_joins_every_worker() {
    model(|| {
        let pool = Pool::new(2);
        pool.shutdown();
        assert_eq!(pool.live_workers(), 0, "shutdown must join every worker");
    });
}
