//! Scoped thread pool + `parallel_for` — our stand-in for the paper's
//! OpenMP parallel loops (rayon is unavailable offline).
//!
//! Design: a fixed set of worker threads parked on a shared injector;
//! `scope()` lets callers borrow stack data (like OpenMP), implemented with
//! `std::thread::scope` under the hood for the borrowed case, and a
//! long-lived pool for the serving path where tasks are `'static`.
//!
//! The "Mobile" configuration of the paper (single ARM core) is modelled by
//! constructing a pool with 1 thread: `parallel_for` then degenerates to a
//! sequential loop with no thread overhead.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// A chunked parallel for-loop over `0..n` with `threads` workers that may
/// borrow from the caller's stack. Each worker receives disjoint index
/// ranges; `body(i)` is called exactly once per index.
///
/// With `threads <= 1` (or tiny `n`) it runs inline — this is the paper's
/// Mobile configuration and also keeps nested parallelism cheap.
pub fn parallel_for<F>(threads: usize, n: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Chunk size balances scheduling overhead vs. load balance; the conv
    // loops have fairly uniform bodies so a modest chunk works well.
    let chunk = (n / (threads * 4)).max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(i);
                }
            });
        }
    });
}

/// Like [`parallel_for`] but the body gets `(worker_id, index)` so workers
/// can keep per-thread scratch.
pub fn parallel_for_with_id<F>(threads: usize, n: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        for i in 0..n {
            body(0, i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunk = (n / (threads * 4)).max(1);
    std::thread::scope(|s| {
        for t in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(t, i);
                }
            });
        }
    });
}

/// A `&mut [T]` smuggled across `parallel_for` workers that write
/// **disjoint** regions (`T` defaults to `f32`; the q16 path shares
/// `&mut [i16]` lowering buffers the same way). Methods (not field
/// access) are used inside closures so edition-2021 disjoint capture
/// grabs the whole (Sync) wrapper rather than the raw pointer field.
///
/// Safety contract: callers must ensure tasks write non-overlapping index
/// ranges; the paper's parallel loops (over output rows / lowered-matrix
/// rows / batch entries) all have this property by construction.
pub struct SharedSlice<T = f32> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(buf: &mut [T]) -> SharedSlice<T> {
        SharedSlice {
            ptr: buf.as_mut_ptr(),
            len: buf.len(),
        }
    }

    /// Reconstruct the full slice. Each caller must touch only its own
    /// disjoint region (see type docs).
    #[allow(clippy::mut_from_ref)]
    pub fn slice(&self) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool for `'static` jobs (the coordinator's workers).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for id in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mec-worker-{id}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped -> shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            handles,
            size,
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Drop the sender and join all workers.
    pub fn shutdown(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_covers_all_indices_once() {
        for threads in [1, 2, 4] {
            let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(threads, 1000, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(4, 0, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let total = AtomicU64::new(0);
        parallel_for(3, 10_000, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn with_id_ids_in_range() {
        let bad = AtomicUsize::new(0);
        parallel_for_with_id(3, 500, |t, _| {
            if t >= 3 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pool_runs_jobs_and_shuts_down() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut pool = ThreadPool::new(2);
        let (tx, rx) = mpsc::channel();
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(());
            });
        }
        for _ in 0..64 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_size_min_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
