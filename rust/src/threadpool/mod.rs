//! Persistent parked worker pool + `parallel_for` — our stand-in for the
//! paper's OpenMP parallel loops (rayon is unavailable offline).
//!
//! # Why a persistent pool
//!
//! MEC's headline schedule executes *many small* matrix multiplications
//! per convolution (§3, Fig. 4): `o_h` (Solution A) or `i_n·o_h`
//! (Solution B) GEMMs whose bodies often run tens of microseconds. The
//! original substrate spawned and joined fresh OS threads via
//! `std::thread::scope` on **every** parallel loop, so a 5-layer model at
//! batch 1 paid ~40+ thread spawns per inference — dispatch cost, not
//! FLOPs, decided the benchmark. [`Pool`] replaces that with long-lived
//! workers that spin briefly and then park between jobs; dispatching a
//! loop is an epoch bump + condvar wake instead of clone+spawn+join.
//!
//! # Shape of the API
//!
//! * [`Pool`] — the workers. Created once (per [`Parallelism`] handle /
//!   per engine), joined on drop. Borrowed-stack closures are supported
//!   the way rayon's scope does it: the closure reference is
//!   lifetime-erased into the job slot, and the submitting thread cannot
//!   return until every registered worker has left the job (completion
//!   barrier), so the borrow is live for exactly as long as any worker
//!   can touch it.
//! * [`Parallelism`] — what the rest of the stack carries (inside
//!   [`ConvContext`](crate::conv::ConvContext)): an optional shared
//!   `Arc<Pool>` plus a *thread budget*, so many sessions can share one
//!   pool while each is capped at its own width, plus the
//!   [`GrainModel`] used to decide when a loop is too small to pay even
//!   a pool wake-up and should run inline.
//! * [`scoped_parallel_for`] — the old spawn-per-call implementation,
//!   kept only as the baseline the dispatch microbench compares against.
//!
//! The "Mobile" configuration of the paper (single ARM core) is modelled
//! by a budget of 1: every loop degenerates to a sequential run with no
//! pool, no spawns, no atomics.
//!
//! # Observability
//!
//! Every OS thread this module ever spawns (pool workers and the scoped
//! baseline) bumps [`os_threads_spawned`]; live pool workers are gauged
//! by [`live_pool_workers`]. A pool additionally counts its own spawns
//! ([`Pool::threads_spawned`]), which is what the steady-state tests
//! assert stays flat across repeated `Session::infer` calls — the
//! threading analogue of the zero-tracked-alloc invariant.

pub mod model;
pub mod sync;

#[cfg(all(loom, test))]
mod loom_tests;

use self::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use self::sync::{Arc, Condvar, Mutex};

/// Total OS threads ever spawned by this module (pool workers + the
/// scoped-spawn baseline), process-wide. Monitoring only — deliberately
/// a real `std` atomic even under `--cfg loom` (not part of the
/// dispatch protocol; modelling it would only inflate the state space).
static OS_THREADS_SPAWNED: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Currently-alive pool workers, process-wide (decremented as workers
/// exit during shutdown — the no-leak tests watch this return to its
/// baseline). Monitoring only; real `std` atomic (see above).
static LIVE_POOL_WORKERS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Total OS threads ever spawned by this module, process-wide.
pub fn os_threads_spawned() -> usize {
    OS_THREADS_SPAWNED.load(std::sync::atomic::Ordering::Acquire)
}

/// Pool workers currently alive, process-wide.
pub fn live_pool_workers() -> usize {
    LIVE_POOL_WORKERS.load(std::sync::atomic::Ordering::Acquire)
}

/// Spins on the epoch ticker before parking on the condvar: long enough
/// to catch the back-to-back loops of one conv layer without a syscall,
/// short enough not to burn a core while a server sits idle.
#[cfg(not(loom))]
const SPIN_ROUNDS: u32 = 1 << 12;

/// Under the model checker every spin iteration is a scheduling point;
/// one round keeps the "ticker observed during spin" path in the
/// explored space without exploding it.
#[cfg(loom)]
const SPIN_ROUNDS: u32 = 1;

/// A parallel-loop job, lifetime-erased into the pool's slot. The
/// submitting thread keeps `func`/`next`/`slots` alive until every
/// registered worker has deregistered (see `CloseGuard`), which is what
/// makes the borrowed-stack closure sound.
#[derive(Clone, Copy)]
struct JobDesc {
    /// `(worker_slot, index)` body; worker slots are `0..threads` with
    /// slot 0 reserved for the submitting thread.
    func: *const (dyn Fn(usize, usize) + Sync),
    /// Shared chunk cursor over `0..n`.
    next: *const AtomicUsize,
    /// Worker-slot allocator (starts at 1; slot 0 is the submitter).
    slots: *const AtomicUsize,
    n: usize,
    chunk: usize,
    /// Max participants including the submitter; late workers that draw
    /// a slot `>= threads` do no work.
    threads: usize,
}

// SAFETY: the raw pointers reference stack data of the submitting
// thread, which blocks until every worker that could dereference them
// has deregistered from the job (the completion barrier in `CloseGuard`).
unsafe impl Send for JobDesc {}

struct JobState {
    job: Option<JobDesc>,
    /// Bumped once per published job; workers snapshot it to tell a new
    /// job from the one they just finished.
    epoch: u64,
    /// Workers currently registered on the published job.
    active: usize,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitter parks here waiting for stragglers.
    done_cv: Condvar,
    /// Mirror of `state.epoch` for the workers' lock-free spin phase.
    epoch: AtomicU64,
    shutdown: AtomicBool,
    /// A worker body panicked; re-raised on the submitting thread.
    panicked: AtomicBool,
    /// Workers of THIS pool currently alive (decremented as they exit).
    live: AtomicUsize,
}

/// Persistent parked worker pool. One `parallel_for` dispatch is an
/// epoch bump + wake; no OS threads are created after construction.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<sync::thread::JoinHandle<()>>>,
    /// Serializes dispatch: a second submitter (another session sharing
    /// the pool, or a nested loop) finds it held and runs inline.
    submit: Mutex<()>,
    workers: usize,
    spawned: AtomicUsize,
    /// Jobs actually published to the workers (the inline fallbacks are
    /// not counted) — lets tests prove a loop went pooled rather than
    /// silently degrading to the inline path.
    dispatched: AtomicU64,
}

/// How a pooled job ended, carried *out* of the submit-guard scope so
/// the re-raise in [`Pool::run`] happens with the dispatch mutex
/// already released — re-raising under the guard would poison it and
/// permanently (and silently) wedge every later loop onto the inline
/// fallback path.
enum JobOutcome {
    Completed,
    /// The submitting thread's own chunks panicked; payload preserved.
    SubmitterPanicked(Box<dyn std::any::Any + Send>),
    /// A pool worker's chunks panicked (flagged, payload stays on the
    /// worker side).
    WorkerPanicked,
}

impl Pool {
    /// Spawn `workers` parked workers (min 1). A pool serving a thread
    /// budget of `t` wants `t - 1` workers: the submitting thread is
    /// always participant 0.
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                job: None,
                epoch: 0,
                active: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            epoch: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            panicked: AtomicBool::new(false),
            live: AtomicUsize::new(0),
        });
        let pool = Pool {
            shared: Arc::clone(&shared),
            handles: Mutex::new(Vec::with_capacity(workers)),
            submit: Mutex::new(()),
            workers,
            spawned: AtomicUsize::new(0),
            dispatched: AtomicU64::new(0),
        };
        let mut handles = pool.handles.lock().unwrap();
        for id in 0..workers {
            let shared = Arc::clone(&shared);
            OS_THREADS_SPAWNED.fetch_add(1, std::sync::atomic::Ordering::AcqRel);
            LIVE_POOL_WORKERS.fetch_add(1, std::sync::atomic::Ordering::AcqRel);
            pool.shared.live.fetch_add(1, Ordering::AcqRel);
            pool.spawned.fetch_add(1, Ordering::AcqRel);
            handles.push(
                sync::thread::Builder::new()
                    .name(format!("mec-pool-{id}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker"),
            );
        }
        drop(handles);
        pool
    }

    /// Worker threads parked in this pool (excludes the submitter).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// OS threads this pool has ever spawned — flat after construction;
    /// the steady-state tests assert exactly that.
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::Acquire)
    }

    /// Workers of this pool currently alive; `workers()` while running,
    /// 0 after [`Pool::shutdown`] returns (it joins them).
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::Acquire)
    }

    /// Jobs published to the workers so far (inline fallbacks excluded).
    /// The panic-recovery regression test asserts this keeps advancing
    /// after a panicking job — i.e. the pool really recovered instead of
    /// silently serving every later loop inline.
    pub fn jobs_dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Acquire)
    }

    /// Run `body(worker_slot, i)` for every `i in 0..n` using up to
    /// `threads` participants (the calling thread is slot 0). Falls back
    /// to an inline loop when the pool is already running a job — which
    /// both serializes concurrent sessions safely and makes nested
    /// parallel loops degenerate instead of deadlocking or
    /// oversubscribing.
    pub fn run(&self, threads: usize, n: usize, body: &(dyn Fn(usize, usize) + Sync)) {
        let threads = threads.min(self.workers + 1).min(n.max(1));
        if threads <= 1 || n <= 1 {
            for i in 0..n {
                body(0, i);
            }
            return;
        }
        // The whole dispatch runs inside this block so the submit guard
        // is released before any panic is re-raised below; the outcome
        // carries the failure across the guard's scope.
        let outcome = {
            let Ok(_submit) = self.submit.try_lock() else {
                for i in 0..n {
                    body(0, i);
                }
                return;
            };
            let next = AtomicUsize::new(0);
            let slots = AtomicUsize::new(1);
            // Chunk size balances scheduling overhead vs. load balance; the
            // conv loops have fairly uniform bodies so a modest chunk works.
            let chunk = (n / (threads * 4)).max(1);
            let desc = JobDesc {
                // SAFETY: lifetime erasure is sound because `CloseGuard`
                // below keeps this frame alive until every registered worker
                // has deregistered — no worker can hold the erased reference
                // past this function's return.
                func: unsafe {
                    std::mem::transmute::<
                        &(dyn Fn(usize, usize) + Sync),
                        &'static (dyn Fn(usize, usize) + Sync),
                    >(body)
                },
                next: &next,
                slots: &slots,
                n,
                chunk,
                threads,
            };
            // A stale flag can survive an aborted previous job; clear it
            // so this job cannot be blamed for it.
            self.shared.panicked.store(false, Ordering::Release);
            {
                let mut st = self.shared.state.lock().unwrap();
                st.epoch += 1;
                st.job = Some(desc);
                self.shared.epoch.store(st.epoch, Ordering::Release);
            }
            self.dispatched.fetch_add(1, Ordering::AcqRel);
            // Wake only as many parked workers as the job can seat (the
            // submitter is participant 0). Spinning workers join on their
            // own via the epoch ticker; latecomers find the slots taken and
            // skip without registering, so a budget-capped job on a big
            // pool never pays wake-ups or barrier waits for idle workers.
            let extra = threads - 1;
            if extra >= self.workers {
                self.shared.work_cv.notify_all();
            } else {
                for _ in 0..extra {
                    self.shared.work_cv.notify_one();
                }
            }
            // Close the job and drain stragglers even if `body` panics on
            // this thread — workers may still hold the erased borrow. The
            // submitter's own chunks are run under `catch_unwind` for the
            // same reason the re-raise is deferred: unwinding through the
            // submit guard would poison it.
            let guard = CloseGuard { shared: &self.shared };
            let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_chunks(&next, n, chunk, 0, body);
            }));
            drop(guard);
            let theirs = self.shared.panicked.swap(false, Ordering::AcqRel);
            match mine {
                Err(payload) => JobOutcome::SubmitterPanicked(payload),
                Ok(()) if theirs => JobOutcome::WorkerPanicked,
                Ok(()) => JobOutcome::Completed,
            }
        };
        match outcome {
            JobOutcome::Completed => {}
            // Propagate exactly one panic per failed job, with the pool
            // fully reusable: the next `run` takes the (unpoisoned)
            // submit lock and dispatches to the workers again.
            JobOutcome::SubmitterPanicked(payload) => std::panic::resume_unwind(payload),
            JobOutcome::WorkerPanicked => {
                panic!("mec::threadpool: a pool worker panicked inside parallel_for")
            }
        }
    }

    /// Park-free check used by tests: true when no job is published.
    pub fn is_idle(&self) -> bool {
        self.shared.state.lock().unwrap().job.is_none()
    }

    /// Ask every worker to exit and join them. Idempotent; called by
    /// `Drop`. A pool used after shutdown still computes correctly —
    /// every loop just runs on the submitting thread.
    pub fn shutdown(&self) {
        {
            let _st = self.shared.state.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("workers", &self.workers).finish()
    }
}

/// Closes the published job and blocks until every registered worker has
/// deregistered — the completion barrier that makes the lifetime erasure
/// in [`Pool::run`] sound (runs in `Drop` so a panicking submitter still
/// waits for its workers).
struct CloseGuard<'p> {
    shared: &'p Shared,
}

impl Drop for CloseGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.job = None;
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
    }
}

fn run_chunks(
    next: &AtomicUsize,
    n: usize,
    chunk: usize,
    slot: usize,
    body: &(dyn Fn(usize, usize) + Sync),
) {
    loop {
        let start = next.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            break;
        }
        let end = (start + chunk).min(n);
        for i in start..end {
            body(slot, i);
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen: u64 = 0;
    'outer: loop {
        // Spin-then-park: watch the epoch ticker lock-free for a while
        // (catches back-to-back layer loops), then block on the condvar.
        let mut spins = 0u32;
        while shared.epoch.load(Ordering::Acquire) == seen
            && !shared.shutdown.load(Ordering::Acquire)
            && spins < SPIN_ROUNDS
        {
            spins += 1;
            sync::spin_loop();
        }
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    break 'outer;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    match st.job {
                        // Register while holding the lock: the submitter
                        // cannot finish closing until we are counted.
                        Some(d) => {
                            // SAFETY: `job` is still `Some` under the
                            // state mutex, so the submitter has not yet
                            // passed its close barrier and the stack
                            // frame holding `slots` is alive.
                            let taken = unsafe { (*d.slots).load(Ordering::Relaxed) };
                            if taken >= d.threads {
                                // Fully seated: skip without registering
                                // so the barrier never waits on us.
                                break None;
                            }
                            st.active += 1;
                            break Some(d);
                        }
                        // Job already closed before we woke: skip it.
                        None => break None,
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let Some(d) = job else { continue };
        // SAFETY: this worker is registered on the job (`st.active` was
        // incremented under the lock above), so the submitter's
        // completion barrier keeps the frame owning `slots` alive until
        // we deregister below.
        let slot = unsafe { (*d.slots).fetch_add(1, Ordering::Relaxed) };
        if slot < d.threads {
            // SAFETY: same barrier argument as `slots` above — the
            // erased closure reference outlives our registration.
            let body = unsafe { &*d.func };
            // SAFETY: same barrier argument; `next` lives in the same
            // submitter frame.
            let next = unsafe { &*d.next };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_chunks(next, d.n, d.chunk, slot, body);
            }));
            if result.is_err() {
                shared.panicked.store(true, Ordering::Release);
            }
        }
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
    shared.live.fetch_sub(1, Ordering::AcqRel);
    LIVE_POOL_WORKERS.fetch_sub(1, std::sync::atomic::Ordering::AcqRel);
}

/// Coefficients for the inline-vs-dispatch decision: what one unit of
/// loop work costs and what waking the parked pool costs. The canonical
/// instance is derived from the planner's calibrated
/// [`CostModel`](crate::planner::CostModel) via
/// [`CostModel::grain_model`](crate::planner::CostModel::grain_model),
/// so the same coefficients that rank algorithms also size the grain —
/// MEC's tiny `o_w`-row GEMMs stay inline instead of paying a wake-up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GrainModel {
    /// ns per multiply-add through the blocked GEMM.
    pub ns_per_mac: f64,
    /// ns per byte moved by lowering/repack/copy loops.
    pub ns_per_byte: f64,
    /// Estimated cost of one pool dispatch (publish + wake + completion
    /// barrier). A loop goes parallel only when the time it stands to
    /// save clears this.
    pub dispatch_ns: f64,
}

impl Default for GrainModel {
    fn default() -> GrainModel {
        // Delegate to the calibrated cost model (same crate, no cycle:
        // CostModel's Default only consults the one-time backend
        // detection) so recalibrating the planner automatically retunes
        // the grain.
        crate::planner::CostModel::default().grain_model()
    }
}

/// The parallel-execution handle the whole stack carries (inside
/// [`ConvContext`](crate::conv::ConvContext)): a shared [`Pool`] plus a
/// per-holder thread budget and the [`GrainModel`] for the inline fast
/// path. Cloning shares the pool; [`Parallelism::with_budget`] caps a
/// clone's width without touching the pool (how serving workers split
/// one engine pool without oversubscribing).
#[derive(Clone)]
pub struct Parallelism {
    budget: usize,
    pool: Option<Arc<Pool>>,
    grain: GrainModel,
}

impl Parallelism {
    /// Budget 1, no pool, no worker threads — the paper's Mobile
    /// configuration; every loop runs sequentially on the caller.
    pub fn inline() -> Parallelism {
        Parallelism {
            budget: 1,
            pool: None,
            grain: GrainModel::default(),
        }
    }

    /// A budget of `threads` with the default grain coefficients;
    /// spawns a pool of `threads - 1` parked workers when `threads > 1`.
    pub fn new(threads: usize) -> Parallelism {
        Parallelism::with_grain(threads, GrainModel::default())
    }

    /// Like [`Parallelism::new`] with explicit grain coefficients (the
    /// planner's [`CostModel`](crate::planner::CostModel) provides the
    /// calibrated instance).
    pub fn with_grain(threads: usize, grain: GrainModel) -> Parallelism {
        let budget = threads.max(1);
        Parallelism {
            budget,
            pool: if budget > 1 {
                Some(Arc::new(Pool::new(budget - 1)))
            } else {
                None
            },
            grain,
        }
    }

    /// A clone sharing this pool, capped at `budget` participants
    /// (clamped to `1..=self.threads()`). Serving workers use this to
    /// divide one engine pool: worker-count × per-session budget stays
    /// at the pool size instead of multiplying.
    pub fn with_budget(&self, budget: usize) -> Parallelism {
        Parallelism {
            budget: budget.clamp(1, self.budget),
            pool: self.pool.clone(),
            grain: self.grain,
        }
    }

    /// The thread budget (≥ 1): max participants per loop, caller
    /// included.
    pub fn threads(&self) -> usize {
        self.budget
    }

    /// The shared pool, if this handle is pooled (budget > 1).
    pub fn pool(&self) -> Option<&Arc<Pool>> {
        self.pool.as_ref()
    }

    /// The grain coefficients in force.
    pub fn grain(&self) -> GrainModel {
        self.grain
    }

    /// A chunked parallel for-loop over `0..n`; `body(i)` is called
    /// exactly once per index, from this thread and/or pool workers.
    /// Runs inline when the budget or `n` is 1, when there is no pool,
    /// or when the pool is busy with another session's loop.
    pub fn parallel_for<F>(&self, n: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        self.dispatch(n, &|_, i| body(i));
    }

    /// Like [`Parallelism::parallel_for`] but the body also receives a
    /// worker slot in `0..self.threads()` (slot 0 is the caller), for
    /// per-thread scratch lanes.
    pub fn parallel_for_with_id<F>(&self, n: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.dispatch(n, &body);
    }

    /// Grain-aware loop: `macs_per_item` estimates each index's GEMM
    /// work; the whole loop runs inline when the estimated saving from
    /// going parallel does not clear one pool dispatch.
    pub fn parallel_for_macs<F>(&self, n: usize, macs_per_item: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let est_ns = (n * macs_per_item) as f64 * self.grain.ns_per_mac;
        if self.should_inline(est_ns) {
            for i in 0..n {
                body(i);
            }
            return;
        }
        self.dispatch(n, &|_, i| body(i));
    }

    /// Grain-aware loop for copy/lowering bodies: `bytes_per_item`
    /// estimates each index's moved bytes (reads + writes).
    pub fn parallel_for_bytes<F>(&self, n: usize, bytes_per_item: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        let est_ns = (n * bytes_per_item) as f64 * self.grain.ns_per_byte;
        if self.should_inline(est_ns) {
            for i in 0..n {
                body(i);
            }
            return;
        }
        self.dispatch(n, &|_, i| body(i));
    }

    /// True when `est_ns` of loop work is too small to pay a pool
    /// wake-up: parallel saves at most `est·(1 − 1/budget)`, which must
    /// clear the dispatch cost.
    pub fn should_inline(&self, est_ns: f64) -> bool {
        if self.budget <= 1 || self.pool.is_none() {
            return true;
        }
        let saved = est_ns * (1.0 - 1.0 / self.budget as f64);
        saved < self.grain.dispatch_ns
    }

    fn dispatch(&self, n: usize, body: &(dyn Fn(usize, usize) + Sync)) {
        let t = self.budget.min(n.max(1));
        if t <= 1 || n <= 1 {
            for i in 0..n {
                body(0, i);
            }
            return;
        }
        match &self.pool {
            Some(pool) => pool.run(t, n, body),
            // A multi-thread budget without a pool never spawns: it runs
            // inline (construction via `new`/`with_grain` always pairs a
            // budget > 1 with a pool, so this is a defensive path).
            None => {
                for i in 0..n {
                    body(0, i);
                }
            }
        }
    }
}

impl std::fmt::Debug for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Parallelism")
            .field("budget", &self.budget)
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

/// The pre-pool implementation: spawn + join fresh scoped threads on
/// every call. Kept **only** as the baseline the dispatch microbench
/// (`cargo bench --bench dispatch`) compares the pool against; no
/// production path calls this.
pub fn scoped_parallel_for<F>(threads: usize, n: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    scoped_parallel_for_with_id(threads, n, |_, i| body(i));
}

/// `(worker_id, index)` variant of [`scoped_parallel_for`].
pub fn scoped_parallel_for_with_id<F>(threads: usize, n: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        for i in 0..n {
            body(0, i);
        }
        return;
    }
    // The scoped baseline is not part of the modelled protocol: it uses
    // real `std` atomics and scoped threads even under `--cfg loom`.
    let next = std::sync::atomic::AtomicUsize::new(0);
    let chunk = (n / (threads * 4)).max(1);
    OS_THREADS_SPAWNED.fetch_add(threads, std::sync::atomic::Ordering::AcqRel);
    std::thread::scope(|s| {
        for t in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(chunk, std::sync::atomic::Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(t, i);
                }
            });
        }
    });
}

/// A `&mut [T]` smuggled across `parallel_for` workers that write
/// **disjoint** regions (`T` defaults to `f32`; the q16 path shares
/// `&mut [i16]` lowering buffers the same way). Methods (not field
/// access) are used inside closures so edition-2021 disjoint capture
/// grabs the whole (Sync) wrapper rather than the raw pointer field.
///
/// Safety contract: callers must ensure tasks write non-overlapping index
/// ranges; the paper's parallel loops (over output rows / lowered-matrix
/// rows / batch entries) all have this property by construction. The
/// pool's completion barrier guarantees the wrapped borrow outlives every
/// worker that can reach it.
pub struct SharedSlice<T = f32> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the wrapped `&mut [T]` outlives the wrapper by construction
// (the pool's completion barrier — or scope, for the baseline — keeps
// the borrow alive for as long as any worker can reach it), and the
// documented contract requires workers to write disjoint regions only.
unsafe impl<T: Send> Send for SharedSlice<T> {}
// SAFETY: see the Send impl above; `Sync` is what lets `&SharedSlice`
// be captured by the `Fn(usize, usize) + Sync` job body.
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(buf: &mut [T]) -> SharedSlice<T> {
        SharedSlice {
            ptr: buf.as_mut_ptr(),
            len: buf.len(),
        }
    }

    /// Reconstruct the full slice. Each caller must touch only its own
    /// disjoint region (see type docs). Prefer [`SharedSlice::range`],
    /// which bounds-checks the caller's window.
    #[allow(clippy::mut_from_ref)]
    pub fn slice(&self) -> &mut [T] {
        // SAFETY: `ptr`/`len` came from a live `&mut [T]` (see `new`);
        // the type's Send/Sync contract makes the holder responsible for
        // disjointness, and the pool barrier for liveness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// The caller's disjoint window `start..start + len`, bounds-checked
    /// against the wrapped slice. Panics (rather than aliasing memory
    /// off the end of the allocation) when the window does not fit —
    /// the misuse guard for hand-computed worker ranges.
    #[allow(clippy::mut_from_ref)]
    pub fn range(&self, start: usize, len: usize) -> &mut [T] {
        let end = start
            .checked_add(len)
            .expect("SharedSlice::range: start + len overflows");
        assert!(
            end <= self.len,
            "SharedSlice::range out of bounds: {start}..{end} exceeds len {}",
            self.len
        );
        // SAFETY: the window was just checked to lie inside the wrapped
        // slice; liveness and cross-worker disjointness are the type's
        // documented contract (see `slice`).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }

    /// Two disjoint windows split at `mid` (panics if `mid > len`) —
    /// the checked way to hand two workers non-overlapping halves.
    #[allow(clippy::mut_from_ref)]
    pub fn split_at(&self, mid: usize) -> (&mut [T], &mut [T]) {
        assert!(
            mid <= self.len,
            "SharedSlice::split_at out of bounds: mid {mid} exceeds len {}",
            self.len
        );
        (self.range(0, mid), self.range(mid, self.len - mid))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

// The concrete-execution tests exercise real threads and timing; under
// `--cfg loom` the facade swaps in the serializing model shims, where
// the interleaving tests in `loom_tests` take over instead.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn pooled_parallel_for_covers_all_indices_once() {
        for threads in [1, 2, 4] {
            let par = Parallelism::new(threads);
            let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
            par.parallel_for(1000, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn pool_is_reused_across_many_loops() {
        let par = Parallelism::new(4);
        let spawned = par.pool().unwrap().threads_spawned();
        assert_eq!(spawned, 3, "budget 4 = caller + 3 workers");
        let total = AtomicU64::new(0);
        for _ in 0..50 {
            par.parallel_for(10_000, |i| {
                total.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * (10_000u64 * 9_999 / 2));
        assert_eq!(
            par.pool().unwrap().threads_spawned(),
            spawned,
            "steady-state loops must not spawn OS threads"
        );
    }

    #[test]
    fn parallel_for_empty_and_single() {
        let par = Parallelism::new(4);
        par.parallel_for(0, |_| panic!("must not run"));
        let hits = AtomicUsize::new(0);
        par.parallel_for(1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn with_id_ids_in_budget_range() {
        let par = Parallelism::new(3);
        let bad = AtomicUsize::new(0);
        par.parallel_for_with_id(500, |t, _| {
            if t >= 3 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn with_budget_caps_worker_ids_and_shares_pool() {
        let par = Parallelism::new(8);
        let capped = par.with_budget(2);
        assert_eq!(capped.threads(), 2);
        assert!(Arc::ptr_eq(par.pool().unwrap(), capped.pool().unwrap()));
        let bad = AtomicUsize::new(0);
        capped.parallel_for_with_id(400, |t, _| {
            if t >= 2 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
        // Budgets clamp into 1..=parent.
        assert_eq!(par.with_budget(0).threads(), 1);
        assert_eq!(par.with_budget(99).threads(), 8);
    }

    #[test]
    fn nested_parallel_for_runs_inline_not_deadlocked() {
        let par = Parallelism::new(4);
        let total = AtomicUsize::new(0);
        let inner = par.clone();
        par.parallel_for(8, |_| {
            inner.parallel_for(8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn grain_cutoff_keeps_tiny_loops_inline() {
        let par = Parallelism::new(4);
        // 8 items × 10 MACs ≈ 36 ns of work: far under any dispatch cost.
        assert!(par.should_inline(8.0 * 10.0 * par.grain().ns_per_mac));
        let hits = AtomicUsize::new(0);
        par.parallel_for_macs(8, 10, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        // A big loop clears the cutoff.
        assert!(!par.should_inline(1e9));
        // Budget 1 is always inline.
        assert!(Parallelism::inline().should_inline(1e12));
    }

    #[test]
    fn pool_shutdown_joins_all_workers() {
        // Pool-local gauge (the global one races with other tests'
        // pools in this parallel-test binary).
        let par = Parallelism::new(6);
        let pool = par.pool().unwrap();
        assert_eq!(pool.workers(), 5);
        assert_eq!(pool.live_workers(), 5);
        pool.shutdown();
        assert_eq!(pool.live_workers(), 0, "shutdown must join every worker");
        // Shutdown pools still compute (inline) — and shutdown is
        // idempotent, so the eventual Drop is a no-op.
        let hits = AtomicUsize::new(0);
        par.parallel_for(10, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let par = Parallelism::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par.parallel_for(1000, |i| {
                if i == 997 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must not be swallowed");
        // The pool is still usable afterwards.
        let hits = AtomicUsize::new(0);
        par.parallel_for(100, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn panicking_job_does_not_wedge_the_pool_onto_the_inline_path() {
        // Regression: `Pool::run` used to re-raise a worker panic while
        // still holding the `submit` mutex guard, poisoning it; every
        // later `try_lock` then failed and every loop silently fell back
        // to the inline path — results stayed correct, so only a
        // dispatch counter can catch it.
        let par = Parallelism::new(4);
        let pool = par.pool().unwrap();
        par.parallel_for(1000, |_| {});
        let base = pool.jobs_dispatched();
        assert!(base >= 1, "warm-up loop must dispatch to the pool");
        // Exactly one panic propagates, through the grain-aware entry
        // point the conv layers use.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par.parallel_for_macs(1000, 1 << 20, |i| {
                if i == 500 {
                    panic!("injected fault");
                }
            });
        }));
        assert!(result.is_err(), "the injected panic must propagate");
        // The next submit completes normally AND goes to the workers.
        let hits = AtomicUsize::new(0);
        par.parallel_for(1000, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert!(
            pool.jobs_dispatched() >= base + 2,
            "post-panic loops must be pooled again (dispatched {} vs base {base}), \
             not silent inline fallbacks",
            pool.jobs_dispatched()
        );
        assert_eq!(pool.threads_spawned(), 3, "recovery must not respawn workers");
        // Submitter-slot panics (index 0 always runs on the caller's
        // first chunk grab unless a worker raced it) take the
        // catch_unwind path; either way the pool must stay pooled.
        for _ in 0..4 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                par.parallel_for(1000, |i| {
                    if i == 0 {
                        panic!("early fault");
                    }
                });
            }));
            assert!(r.is_err());
        }
        let before = pool.jobs_dispatched();
        par.parallel_for(1000, |_| {});
        assert!(pool.jobs_dispatched() > before);
    }

    #[test]
    fn scoped_baseline_still_correct_and_counted() {
        let before = os_threads_spawned();
        let total = AtomicU64::new(0);
        scoped_parallel_for(3, 10_000, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
        assert!(
            os_threads_spawned() >= before + 3,
            "baseline spawns are counted"
        );
    }

    #[test]
    fn shared_slice_range_and_split_cover_exactly() {
        let mut buf = vec![0u32; 10];
        let sh = SharedSlice::new(&mut buf);
        assert_eq!(sh.len(), 10);
        assert!(!sh.is_empty());
        sh.range(0, 4).fill(1);
        sh.range(4, 6).fill(2);
        let (a, b) = sh.split_at(7);
        assert_eq!(a.len(), 7);
        assert_eq!(b.len(), 3);
        // Degenerate but legal windows.
        assert_eq!(sh.range(10, 0).len(), 0);
        assert_eq!(sh.split_at(0).0.len(), 0);
        drop(sh);
        assert_eq!(buf, [1, 1, 1, 1, 2, 2, 2, 2, 2, 2]);
    }

    #[test]
    fn shared_slice_out_of_bounds_range_panics() {
        let mut buf = vec![0.0f32; 8];
        let sh = SharedSlice::new(&mut buf);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = sh.range(4, 5);
        }));
        assert!(r.is_err(), "window past the end must panic, not alias");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = sh.range(usize::MAX, 2);
        }));
        assert!(r.is_err(), "start+len overflow must panic");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = sh.split_at(9);
        }));
        assert!(r.is_err(), "split point past the end must panic");
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes_land() {
        let par = Parallelism::new(4);
        let mut buf = vec![0usize; 64];
        let sh = SharedSlice::new(&mut buf);
        par.parallel_for(8, |i| {
            let lane = sh.range(i * 8, 8);
            for (k, v) in lane.iter_mut().enumerate() {
                *v = i * 8 + k;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn concurrent_submitters_share_one_pool_safely() {
        let par = Parallelism::new(4);
        let total = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let par = par.clone();
                let total = Arc::clone(&total);
                s.spawn(move || {
                    for _ in 0..20 {
                        par.parallel_for(1000, |i| {
                            total.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * (1000u64 * 999 / 2));
    }
}
