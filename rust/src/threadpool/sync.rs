//! Sync facade for the threadpool: `std::sync` in normal builds, the
//! in-tree bounded model checker ([`super::model`]) under
//! `RUSTFLAGS="--cfg loom"`.
//!
//! Everything concurrency-relevant in [`super`] (the pool's mutex,
//! condvars, protocol atomics, worker threads, and the spin hint) is
//! imported from here rather than from `std` directly, so the exact
//! production dispatch/claim/barrier/panic protocol can be compiled
//! against the model checker's serializing shims and explored
//! exhaustively. Monitoring-only counters (spawn gauges) intentionally
//! stay on real `std` atomics even under `--cfg loom`: they are not part
//! of the protocol, and modelling them would only inflate the
//! interleaving space.
//!
//! The name `loom` is kept for the cfg switch because it is the
//! ecosystem's conventional flag for "compile the sync facade against a
//! model checker" (the `loom` crate popularized it); vendoring the real
//! crate is not possible offline, so [`super::model`] provides the same
//! role: serialized threads, exhaustive bounded interleaving search,
//! deadlock detection.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{Builder, JoinHandle};
}

/// CPU relax hint in the workers' lock-free spin phase. Under the model
/// this is an explicit scheduling point instead, so the checker can
/// interleave other threads where real hardware would.
#[cfg(not(loom))]
#[inline]
pub fn spin_loop() {
    std::hint::spin_loop();
}

#[cfg(loom)]
pub use std::sync::Arc;

#[cfg(loom)]
pub use super::model::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub mod atomic {
    pub use crate::threadpool::model::{AtomicBool, AtomicU64, AtomicUsize};
    pub use std::sync::atomic::Ordering;
}

#[cfg(loom)]
pub mod thread {
    pub use crate::threadpool::model::thread::{Builder, JoinHandle};
}

#[cfg(loom)]
#[inline]
pub fn spin_loop() {
    super::model::yield_now();
}
