//! An in-tree bounded model checker for the pool's sync protocol — the
//! `loom::sync` role behind [`super::sync`] (the real `loom` crate is
//! not vendorable offline).
//!
//! # How it works (CHESS-style systematic concurrency testing)
//!
//! [`model`] runs a test closure many times. Each run spawns real OS
//! threads, but a scheduler serializes them completely: exactly one
//! thread holds the "active" token at a time, and every visible
//! operation on a model sync primitive (atomic load/store/RMW, mutex
//! lock, condvar wait/notify, join) is a *scheduling point* where the
//! checker may hand the token to another runnable thread. The sequence
//! of scheduling decisions is recorded; after each run the checker
//! backtracks depth-first to the deepest decision with an unexplored
//! alternative and replays, until the bounded space is exhausted.
//!
//! Bounds, tuned by environment variables (names follow loom's):
//!
//! * `LOOM_MAX_PREEMPTIONS` (default 2) — max *preemptive* context
//!   switches per execution (switching away from a thread that could
//!   have continued). Switches at blocking points are free. Two
//!   preemptions find the overwhelming majority of real schedule bugs
//!   (the CHESS result) while keeping the space polynomial.
//! * `LOOM_MAX_ITERATIONS` (default 200k) — execution-count cap; hitting
//!   it prints a truncation warning rather than failing.
//! * `LOOM_MAX_STEPS` (default 200k) — per-execution scheduling-point
//!   cap; exceeding it is reported as a livelock violation.
//!
//! # What a violation looks like
//!
//! Deadlock (no runnable thread while some are blocked), livelock (step
//! cap), a leaked thread at closure end, or any panic from the closure
//! body (assertion failures included) fails the test with a panic. On a
//! violation the checker deliberately *leaks* the other parked threads
//! for that execution instead of unwinding through them — unwinding
//! foreign stacks from inside `Drop` impls would risk a double-panic
//! abort and hide the report.
//!
//! # Model fidelity
//!
//! This checker explores *sequentially consistent* executions only:
//! `Ordering` arguments are accepted and ignored. It will therefore not
//! find bugs that require observing relaxed/reordered memory (loom's
//! extra power); it does find lost wakeups, lost updates, double claims,
//! barrier misuse, and deadlocks — the failure classes the pool protocol
//! actually risks. Condvars never wake spuriously in the model, and
//! `notify_one` wakes the longest-waiting thread deterministically.

use std::any::Any;
use std::cell::{RefCell, UnsafeCell};
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex};

type ThreadResult = Result<Box<dyn Any + Send>, Box<dyn Any + Send>>;

// ---------------------------------------------------------------------------
// Scheduler core
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    Runnable,
    Blocked,
    Finished,
}

struct ThreadRec {
    state: TState,
    joiners: Vec<usize>,
    result: Option<ThreadResult>,
}

impl ThreadRec {
    fn new() -> ThreadRec {
        ThreadRec {
            state: TState::Runnable,
            joiners: Vec::new(),
            result: None,
        }
    }
}

/// One recorded scheduling decision: which of `options` legal successor
/// threads was chosen. Only points with more than one legal option are
/// recorded (single-option points cannot branch).
#[derive(Clone, Copy)]
struct ChoicePoint {
    chosen: usize,
    options: usize,
}

struct SchedState {
    /// Thread currently holding the run token.
    active: usize,
    threads: Vec<ThreadRec>,
    preemptions: usize,
    bound: usize,
    /// Forced decision prefix for this execution (from backtracking).
    replay: Vec<usize>,
    /// Decisions taken so far (index into `replay` while it lasts).
    decided: usize,
    path: Vec<ChoicePoint>,
    steps: usize,
    max_steps: usize,
    failed: Option<String>,
}

struct Sched {
    m: StdMutex<SchedState>,
    cv: StdCondvar,
    /// Real OS handles of this execution's model threads, joined at the
    /// end of a clean execution (leaked on violation — see module docs).
    real: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

#[derive(Clone)]
struct Ctx {
    sched: StdArc<Sched>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// Clears the thread-local execution context on drop, so a violation
/// panic unwinding out of the test closure leaves no stale scheduler
/// behind on the harness thread.
struct CtxGuard;

impl CtxGuard {
    fn set(sched: StdArc<Sched>, tid: usize) -> CtxGuard {
        CTX.with(|c| *c.borrow_mut() = Some(Ctx { sched, tid }));
        CtxGuard
    }
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CTX.with(|c| *c.borrow_mut() = None);
    }
}

impl Sched {
    fn new(replay: Vec<usize>, bound: usize, max_steps: usize) -> Sched {
        Sched {
            m: StdMutex::new(SchedState {
                active: 0,
                threads: vec![ThreadRec::new()],
                preemptions: 0,
                bound,
                replay,
                decided: 0,
                path: Vec::new(),
                steps: 0,
                max_steps,
                failed: None,
            }),
            cv: StdCondvar::new(),
            real: StdMutex::new(Vec::new()),
        }
    }

    /// Record the violation, wake every parked thread so each can raise
    /// it, and raise it here. Never returns.
    fn fail_locked(&self, mut st: std::sync::MutexGuard<'_, SchedState>, msg: &str) -> ! {
        let full = format!("mec model checker: {msg}");
        st.failed = Some(full.clone());
        self.cv.notify_all();
        drop(st);
        panic!("{full}");
    }

    /// Scheduling point for the active thread. `runnable` says whether
    /// the caller may keep the token (false = it just blocked and its
    /// `ThreadRec` state is already non-runnable). Returns once the
    /// caller is active again.
    fn reschedule_locked(&self, mut st: std::sync::MutexGuard<'_, SchedState>, me: usize, runnable: bool) {
        if let Some(msg) = st.failed.clone() {
            drop(st);
            if std::thread::panicking() {
                return;
            }
            panic!("{msg}");
        }
        st.steps += 1;
        if st.steps > st.max_steps {
            let cap = st.max_steps;
            self.fail_locked(st, &format!("step cap ({cap}) exceeded: livelock or unbounded loop"));
        }
        let mut options: Vec<usize> = Vec::new();
        if runnable {
            options.push(me);
        }
        // Switching away from a still-runnable thread is a preemption
        // and only legal under the bound; switching off a blocked
        // thread is free.
        if !runnable || st.preemptions < st.bound {
            for (tid, rec) in st.threads.iter().enumerate() {
                if tid != me && rec.state == TState::Runnable {
                    options.push(tid);
                }
            }
        }
        if options.is_empty() {
            if st.threads.iter().all(|t| t.state == TState::Finished) {
                return;
            }
            self.fail_locked(st, "deadlock: every live thread is blocked");
        }
        let chosen = Self::choose_locked(&mut st, &options);
        if chosen == me {
            return;
        }
        if runnable {
            st.preemptions += 1;
        }
        st.active = chosen;
        self.cv.notify_all();
        while st.active != me {
            if let Some(msg) = st.failed.clone() {
                drop(st);
                panic!("{msg}");
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Pick the next thread among `options` (preferred-first order),
    /// consuming the replay prefix and recording branchable decisions.
    fn choose_locked(st: &mut SchedState, options: &[usize]) -> usize {
        if options.len() == 1 {
            return options[0];
        }
        let idx = if st.decided < st.replay.len() {
            st.replay[st.decided].min(options.len() - 1)
        } else {
            0
        };
        st.decided += 1;
        st.path.push(ChoicePoint {
            chosen: idx,
            options: options.len(),
        });
        options[idx]
    }

    /// Interleaving point before a visible operation.
    fn yield_active(&self, me: usize) {
        let st = self.m.lock().unwrap();
        self.reschedule_locked(st, me, true);
    }

    fn register_thread(&self) -> usize {
        let mut st = self.m.lock().unwrap();
        st.threads.push(ThreadRec::new());
        st.threads.len() - 1
    }

    /// Park a freshly spawned model thread until first scheduled.
    fn wait_first_schedule(&self, me: usize) {
        let mut st = self.m.lock().unwrap();
        while st.active != me {
            if let Some(msg) = st.failed.clone() {
                drop(st);
                panic!("{msg}");
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// A model thread's closure returned (or panicked, caught): record
    /// the result, wake joiners, and hand the token onward.
    fn finish_thread(&self, me: usize, result: ThreadResult) {
        let mut st = self.m.lock().unwrap();
        st.threads[me].state = TState::Finished;
        st.threads[me].result = Some(result);
        let joiners = std::mem::take(&mut st.threads[me].joiners);
        for j in joiners {
            st.threads[j].state = TState::Runnable;
        }
        if st.failed.is_some() {
            // Execution already condemned; just let this thread exit.
            return;
        }
        let options: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == TState::Runnable)
            .map(|(tid, _)| tid)
            .collect();
        if options.is_empty() {
            if st.threads.iter().all(|t| t.state == TState::Finished) {
                return;
            }
            self.fail_locked(st, "deadlock: every live thread is blocked");
        }
        let chosen = Self::choose_locked(&mut st, &options);
        st.active = chosen;
        self.cv.notify_all();
    }

    fn join_thread(&self, me: usize, target: usize) -> ThreadResult {
        self.yield_active(me);
        loop {
            let mut st = self.m.lock().unwrap();
            if let Some(msg) = st.failed.clone() {
                if std::thread::panicking() {
                    return Err(Box::new("model join passthrough during failure unwind"));
                }
                drop(st);
                panic!("{msg}");
            }
            if st.threads[target].state == TState::Finished {
                return st
                    .threads[target]
                    .result
                    .take()
                    .unwrap_or_else(|| Err(Box::new("model thread joined twice")));
            }
            st.threads[target].joiners.push(me);
            st.threads[me].state = TState::Blocked;
            self.reschedule_locked(st, me, false);
        }
    }

    /// Try to take `mx` for thread `me`; on contention, block and
    /// return `false` once rescheduled (caller retries).
    fn mutex_acquire(&self, me: usize, mx: &UnsafeCell<MxState>) -> bool {
        let mut st = self.m.lock().unwrap();
        if let Some(msg) = st.failed.clone() {
            if std::thread::panicking() {
                return true;
            }
            drop(st);
            panic!("{msg}");
        }
        // SAFETY: mutex protocol state is only touched while holding the
        // scheduler lock, and only one model thread runs at a time, so
        // this &mut is exclusive.
        let s = unsafe { &mut *mx.get() };
        if !s.locked {
            s.locked = true;
            return true;
        }
        s.waiters.push(me);
        st.threads[me].state = TState::Blocked;
        self.reschedule_locked(st, me, false);
        false
    }

    fn mutex_try_acquire(&self, mx: &UnsafeCell<MxState>) -> bool {
        let st = self.m.lock().unwrap();
        if st.failed.is_some() && std::thread::panicking() {
            return true;
        }
        // SAFETY: scheduler lock held; single active thread (see
        // `mutex_acquire`).
        let s = unsafe { &mut *mx.get() };
        if s.locked {
            false
        } else {
            s.locked = true;
            true
        }
    }

    fn mutex_release(&self, mx: &UnsafeCell<MxState>) {
        let mut st = self.m.lock().unwrap();
        // SAFETY: scheduler lock held; single active thread (see
        // `mutex_acquire`).
        let s = unsafe { &mut *mx.get() };
        s.locked = false;
        if st.failed.is_some() {
            return;
        }
        let waiters = std::mem::take(&mut s.waiters);
        for w in waiters {
            st.threads[w].state = TState::Runnable;
        }
    }

    /// Atomically: register on the condvar, release the mutex, block.
    /// The atomicity (one scheduler critical section) is exactly what
    /// rules out the lost-wakeup window between unlock and sleep.
    fn condvar_wait(&self, me: usize, cv: &UnsafeCell<Vec<usize>>, mx: &UnsafeCell<MxState>) {
        let mut st = self.m.lock().unwrap();
        if let Some(msg) = st.failed.clone() {
            if std::thread::panicking() {
                // SAFETY: scheduler lock held; single active thread.
                let s = unsafe { &mut *mx.get() };
                s.locked = false;
                return;
            }
            drop(st);
            panic!("{msg}");
        }
        // SAFETY: condvar waiter list is only touched while holding the
        // scheduler lock; single active thread.
        let w = unsafe { &mut *cv.get() };
        w.push(me);
        // SAFETY: scheduler lock held; single active thread (see
        // `mutex_acquire`).
        let s = unsafe { &mut *mx.get() };
        s.locked = false;
        let waiters = std::mem::take(&mut s.waiters);
        for t in waiters {
            st.threads[t].state = TState::Runnable;
        }
        st.threads[me].state = TState::Blocked;
        self.reschedule_locked(st, me, false);
    }

    /// Wake up to `n` waiters, FIFO.
    fn condvar_notify(&self, cv: &UnsafeCell<Vec<usize>>, n: usize) {
        let mut st = self.m.lock().unwrap();
        if st.failed.is_some() {
            return;
        }
        // SAFETY: scheduler lock held; single active thread.
        let w = unsafe { &mut *cv.get() };
        let take = w.len().min(n);
        for t in w.drain(..take) {
            st.threads[t].state = TState::Runnable;
        }
    }

    /// The test closure returned on thread 0: every spawned thread must
    /// have finished (the pool joins its workers on drop).
    fn finish_main(&self) {
        let mut st = self.m.lock().unwrap();
        if let Some(msg) = st.failed.clone() {
            drop(st);
            if std::thread::panicking() {
                return;
            }
            panic!("{msg}");
        }
        st.threads[0].state = TState::Finished;
        if st.threads.iter().any(|t| t.state != TState::Finished) {
            self.fail_locked(st, "threads leaked at end of execution: join every spawned thread");
        }
    }
}

/// Scheduling point usable by the active thread (no-op outside a model
/// execution). The `--cfg loom` spin hint maps here.
pub fn yield_now() {
    if let Some(c) = ctx() {
        c.sched.yield_active(c.tid);
    }
}

// ---------------------------------------------------------------------------
// Exploration driver
// ---------------------------------------------------------------------------

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(default)
}

/// Programmatic knobs for one exploration (env-independent, so tests can
/// pin bounds without racing on process environment).
#[derive(Debug, Clone, Copy)]
pub struct Builder {
    /// Max preemptive context switches per execution.
    pub preemption_bound: usize,
    /// Max executions before truncating the search.
    pub max_iterations: usize,
    /// Max scheduling points per execution (livelock guard).
    pub max_steps: usize,
}

impl Builder {
    /// Bounds from `LOOM_MAX_PREEMPTIONS` / `LOOM_MAX_ITERATIONS` /
    /// `LOOM_MAX_STEPS`, with the module-level defaults.
    pub fn from_env() -> Builder {
        Builder {
            preemption_bound: env_usize("LOOM_MAX_PREEMPTIONS", 2),
            max_iterations: env_usize("LOOM_MAX_ITERATIONS", 200_000),
            max_steps: env_usize("LOOM_MAX_STEPS", 200_000),
        }
    }

    /// Exhaustively (within bounds) explore interleavings of `f`,
    /// returning how many executions ran. Panics on the first violation.
    pub fn check<F: Fn()>(&self, f: F) -> usize {
        let mut replay: Vec<usize> = Vec::new();
        let mut iterations = 0usize;
        loop {
            iterations += 1;
            let sched = StdArc::new(Sched::new(replay.clone(), self.preemption_bound, self.max_steps));
            {
                let _guard = CtxGuard::set(StdArc::clone(&sched), 0);
                f();
                sched.finish_main();
            }
            // Clean execution: the model threads have all finished;
            // reap their OS handles before the next iteration.
            for h in sched.real.lock().unwrap().drain(..) {
                let _ = h.join();
            }
            let path = sched.m.lock().unwrap().path.clone();
            match next_replay(&path) {
                None => break,
                Some(_) if iterations >= self.max_iterations => {
                    eprintln!(
                        "mec model checker: iteration cap ({}) hit; exploration truncated",
                        self.max_iterations
                    );
                    break;
                }
                Some(r) => replay = r,
            }
        }
        iterations
    }
}

impl Default for Builder {
    fn default() -> Builder {
        Builder::from_env()
    }
}

/// Depth-first backtracking: advance the deepest decision that still has
/// an unexplored alternative; `None` when the bounded space is done.
fn next_replay(path: &[ChoicePoint]) -> Option<Vec<usize>> {
    for i in (0..path.len()).rev() {
        if path[i].chosen + 1 < path[i].options {
            let mut r: Vec<usize> = path[..i].iter().map(|c| c.chosen).collect();
            r.push(path[i].chosen + 1);
            return Some(r);
        }
    }
    None
}

/// Explore every bounded interleaving of `f` (bounds from the
/// environment — see module docs). Returns the execution count; panics
/// on the first violation. The entry point `--cfg loom` tests use.
pub fn model<F: Fn()>(f: F) -> usize {
    Builder::from_env().check(f)
}

// ---------------------------------------------------------------------------
// Sync shims
// ---------------------------------------------------------------------------

struct MxState {
    locked: bool,
    waiters: Vec<usize>,
}

/// Model mutex: same shape as `std::sync::Mutex` (lock / try_lock /
/// guard), checked blocking semantics, no poisoning (lock results are
/// always `Ok`, so `.lock().unwrap()` code compiles against both).
pub struct Mutex<T> {
    state: UnsafeCell<MxState>,
    data: UnsafeCell<T>,
}

// SAFETY: the scheduler serializes all access — exactly one model thread
// runs at a time, and token handoffs synchronize through a real mutex,
// so sending/sharing the cells across model threads cannot race.
unsafe impl<T: Send> Send for Mutex<T> {}
// SAFETY: see the Send impl above.
unsafe impl<T: Send> Sync for Mutex<T> {}

/// `try_lock` contention marker (stands in for `std`'s `TryLockError`).
#[derive(Debug)]
pub struct WouldBlock;

impl<T> Mutex<T> {
    pub fn new(data: T) -> Mutex<T> {
        Mutex {
            state: UnsafeCell::new(MxState {
                locked: false,
                waiters: Vec::new(),
            }),
            data: UnsafeCell::new(data),
        }
    }

    pub fn lock(&self) -> Result<MutexGuard<'_, T>, std::convert::Infallible> {
        match ctx() {
            Some(c) => {
                c.sched.yield_active(c.tid);
                while !c.sched.mutex_acquire(c.tid, &self.state) {}
            }
            None => {
                // Outside a model execution (single-threaded passthrough).
                // SAFETY: no concurrent model threads exist without a
                // scheduler context, so this access is exclusive.
                let s = unsafe { &mut *self.state.get() };
                debug_assert!(!s.locked, "model Mutex relocked outside a model execution");
                s.locked = true;
            }
        }
        Ok(MutexGuard { lock: self })
    }

    pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, WouldBlock> {
        let ok = match ctx() {
            Some(c) => {
                c.sched.yield_active(c.tid);
                c.sched.mutex_try_acquire(&self.state)
            }
            None => {
                // SAFETY: single-threaded passthrough (see `lock`).
                let s = unsafe { &mut *self.state.get() };
                if s.locked {
                    false
                } else {
                    s.locked = true;
                    true
                }
            }
        };
        if ok {
            Ok(MutexGuard { lock: self })
        } else {
            Err(WouldBlock)
        }
    }
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: the guard exists, so this thread holds the model lock;
        // lock acquisition is serialized by the scheduler.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: see `Deref` — exclusive while the guard lives.
        unsafe { &mut *self.lock.data.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        match ctx() {
            Some(c) => c.sched.mutex_release(&self.lock.state),
            None => {
                // SAFETY: single-threaded passthrough (see `Mutex::lock`).
                let s = unsafe { &mut *self.lock.state.get() };
                s.locked = false;
            }
        }
    }
}

/// Model condvar: FIFO wakeups, no spurious wakes, and the
/// register-unlock-block step is one atomic scheduler action (the exact
/// property that makes real condvars lose no wakeups).
#[derive(Default)]
pub struct Condvar {
    waiters: UnsafeCell<Vec<usize>>,
}

// SAFETY: the waiter list is only touched under the scheduler lock by
// the single active thread (see `Mutex`'s Send/Sync note).
unsafe impl Send for Condvar {}
// SAFETY: see the Send impl above.
unsafe impl Sync for Condvar {}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar::default()
    }

    pub fn wait<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
    ) -> Result<MutexGuard<'a, T>, std::convert::Infallible> {
        let lock = guard.lock;
        let c = ctx().expect("model Condvar::wait outside a model execution");
        c.sched.condvar_wait(c.tid, &self.waiters, &lock.state);
        // The scheduler already released the mutex inside condvar_wait;
        // skip the guard's unlock and re-acquire fresh.
        std::mem::forget(guard);
        lock.lock()
    }

    pub fn notify_one(&self) {
        if let Some(c) = ctx() {
            c.sched.yield_active(c.tid);
            c.sched.condvar_notify(&self.waiters, 1);
        }
    }

    pub fn notify_all(&self) {
        if let Some(c) = ctx() {
            c.sched.yield_active(c.tid);
            c.sched.condvar_notify(&self.waiters, usize::MAX);
        }
    }
}

macro_rules! model_atomic {
    ($(#[$doc:meta])* $name:ident, $ty:ty) => {
        $(#[$doc])*
        pub struct $name {
            v: UnsafeCell<$ty>,
        }

        // SAFETY: only the single active model thread dereferences `v`
        // between scheduler handoffs, and handoffs synchronize through a
        // real mutex — accesses are serialized with happens-before edges.
        unsafe impl Send for $name {}
        // SAFETY: see the Send impl above.
        unsafe impl Sync for $name {}

        impl $name {
            pub const fn new(v: $ty) -> $name {
                $name { v: UnsafeCell::new(v) }
            }

            pub fn load(&self, _order: Ordering) -> $ty {
                yield_now();
                // SAFETY: serialized by the scheduler (see Send impl).
                unsafe { *self.v.get() }
            }

            pub fn store(&self, val: $ty, _order: Ordering) {
                yield_now();
                // SAFETY: serialized by the scheduler (see Send impl).
                unsafe { *self.v.get() = val }
            }

            pub fn swap(&self, val: $ty, _order: Ordering) -> $ty {
                yield_now();
                // SAFETY: serialized by the scheduler (see Send impl).
                unsafe { std::mem::replace(&mut *self.v.get(), val) }
            }

            pub fn fetch_add(&self, val: $ty, _order: Ordering) -> $ty {
                yield_now();
                // SAFETY: serialized by the scheduler (see Send impl).
                unsafe {
                    let p = self.v.get();
                    let old = *p;
                    *p = old.wrapping_add(val);
                    old
                }
            }

            pub fn fetch_sub(&self, val: $ty, _order: Ordering) -> $ty {
                yield_now();
                // SAFETY: serialized by the scheduler (see Send impl).
                unsafe {
                    let p = self.v.get();
                    let old = *p;
                    *p = old.wrapping_sub(val);
                    old
                }
            }
        }
    };
}

model_atomic!(
    /// Model `AtomicUsize`: every op is a scheduling point; `Ordering`
    /// is accepted and ignored (sequential consistency only).
    AtomicUsize,
    usize
);
model_atomic!(
    /// Model `AtomicU64` (see [`AtomicUsize`]).
    AtomicU64,
    u64
);

/// Model `AtomicBool` (see [`AtomicUsize`]).
pub struct AtomicBool {
    v: UnsafeCell<bool>,
}

// SAFETY: serialized by the scheduler (see the model_atomic note).
unsafe impl Send for AtomicBool {}
// SAFETY: see the Send impl above.
unsafe impl Sync for AtomicBool {}

impl AtomicBool {
    pub const fn new(v: bool) -> AtomicBool {
        AtomicBool { v: UnsafeCell::new(v) }
    }

    pub fn load(&self, _order: Ordering) -> bool {
        yield_now();
        // SAFETY: serialized by the scheduler.
        unsafe { *self.v.get() }
    }

    pub fn store(&self, val: bool, _order: Ordering) {
        yield_now();
        // SAFETY: serialized by the scheduler.
        unsafe { *self.v.get() = val }
    }

    pub fn swap(&self, val: bool, _order: Ordering) -> bool {
        yield_now();
        // SAFETY: serialized by the scheduler.
        unsafe { std::mem::replace(&mut *self.v.get(), val) }
    }
}

/// Model threads: real OS threads fully serialized by the scheduler.
pub mod thread {
    use super::*;

    /// Mirror of `std::thread::Builder` (the name is kept for log
    /// readability but the model assigns its own thread names).
    #[derive(Default)]
    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Builder {
            Builder::default()
        }

        pub fn name(mut self, name: String) -> Builder {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            Ok(spawn_named(self.name, f))
        }
    }

    pub struct JoinHandle<T> {
        tid: usize,
        _result: PhantomData<T>,
    }

    impl<T: 'static> JoinHandle<T> {
        /// Block (in model time) until the target finishes; a panic in
        /// the target is returned as `Err(payload)`, like std.
        pub fn join(self) -> Result<T, Box<dyn Any + Send>> {
            let c = ctx().expect("model join outside a model execution");
            match c.sched.join_thread(c.tid, self.tid) {
                Ok(v) => match v.downcast::<T>() {
                    Ok(b) => Ok(*b),
                    Err(_) => Err(Box::new("model join: unexpected result type")
                        as Box<dyn Any + Send>),
                },
                Err(e) => Err(e),
            }
        }
    }

    /// Spawn a model thread (runnable, parked until first scheduled).
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        spawn_named(None, f)
    }

    fn spawn_named<F, T>(name: Option<String>, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let c = ctx().expect("model thread::spawn outside a model execution");
        let tid = c.sched.register_thread();
        let sched = StdArc::clone(&c.sched);
        let real = std::thread::Builder::new()
            .name(name.unwrap_or_else(|| format!("mec-model-{tid}")))
            .spawn(move || {
                let _guard = CtxGuard::set(StdArc::clone(&sched), tid);
                let result = catch_unwind(AssertUnwindSafe(|| {
                    sched.wait_first_schedule(tid);
                    f()
                }));
                let boxed: ThreadResult = match result {
                    Ok(v) => Ok(Box::new(v)),
                    Err(e) => Err(e),
                };
                sched.finish_thread(tid, boxed);
            })
            .expect("spawn model OS thread");
        c.sched.real.lock().unwrap().push(real);
        JoinHandle {
            tid,
            _result: PhantomData,
        }
    }
}

// ---------------------------------------------------------------------------
// Self-tests: these run in ordinary (non-loom) tier-1 builds, so the
// checker itself is covered by `cargo test` before CI trusts it to
// check the pool.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::thread;
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::Ordering::SeqCst;

    fn small() -> Builder {
        Builder {
            preemption_bound: 2,
            max_iterations: 50_000,
            max_steps: 50_000,
        }
    }

    #[test]
    fn model_single_thread_program_runs_exactly_once() {
        let n = small().check(|| {
            let a = AtomicUsize::new(0);
            a.store(3, SeqCst);
            assert_eq!(a.load(SeqCst), 3);
        });
        assert_eq!(n, 1, "no concurrency, no branching");
    }

    #[test]
    fn model_explores_multiple_interleavings() {
        let n = small().check(|| {
            let a = StdArc::new(AtomicUsize::new(0));
            let a2 = StdArc::clone(&a);
            let t = thread::spawn(move || {
                a2.fetch_add(1, SeqCst);
            });
            a.fetch_add(1, SeqCst);
            t.join().unwrap();
            // fetch_add is atomic in the model: never a lost update.
            assert_eq!(a.load(SeqCst), 2);
        });
        assert!(n > 1, "two racing threads must branch, got {n} execution(s)");
    }

    #[test]
    fn model_catches_lost_update() {
        // Non-atomic read-modify-write: some interleaving loses an
        // update, and the checker must find it within 2 preemptions.
        let r = catch_unwind(AssertUnwindSafe(|| {
            small().check(|| {
                let a = StdArc::new(AtomicUsize::new(0));
                let a2 = StdArc::clone(&a);
                let t = thread::spawn(move || {
                    let v = a2.load(SeqCst);
                    a2.store(v + 1, SeqCst);
                });
                let v = a.load(SeqCst);
                a.store(v + 1, SeqCst);
                t.join().unwrap();
                assert_eq!(a.load(SeqCst), 2, "lost update");
            });
        }));
        assert!(r.is_err(), "the racy schedule must be found and reported");
    }

    #[test]
    fn model_mutex_preserves_read_modify_write() {
        // The same read-modify-write, now under the model mutex: every
        // interleaving must keep both increments.
        small().check(|| {
            let m = StdArc::new(Mutex::new(0usize));
            let m2 = StdArc::clone(&m);
            let t = thread::spawn(move || {
                let mut g = m2.lock().unwrap();
                let v = *g;
                yield_now();
                *g = v + 1;
            });
            {
                let mut g = m.lock().unwrap();
                let v = *g;
                yield_now();
                *g = v + 1;
            }
            t.join().unwrap();
            assert_eq!(*m.lock().unwrap(), 2);
        });
    }

    #[test]
    fn model_detects_deadlock() {
        // Classic lock-order inversion: thread 0 takes b then a, the
        // spawned thread takes a then b.
        let r = catch_unwind(AssertUnwindSafe(|| {
            small().check(|| {
                let a = StdArc::new(Mutex::new(()));
                let b = StdArc::new(Mutex::new(()));
                let (a2, b2) = (StdArc::clone(&a), StdArc::clone(&b));
                let t = thread::spawn(move || {
                    let _x = a2.lock().unwrap();
                    let _y = b2.lock().unwrap();
                });
                {
                    let _y = b.lock().unwrap();
                    let _x = a.lock().unwrap();
                }
                t.join().unwrap();
            });
        }));
        assert!(r.is_err(), "the deadlocking schedule must be reported");
    }

    #[test]
    fn model_condvar_never_loses_the_wakeup() {
        // Exhaustive check of the flag+condvar handoff: if any schedule
        // could lose the notify, the blocked waiter would be reported as
        // a deadlock. Completing without panic is the proof.
        small().check(|| {
            let pair = StdArc::new((Mutex::new(false), Condvar::new()));
            let p2 = StdArc::clone(&pair);
            let t = thread::spawn(move || {
                let (m, cv) = &*p2;
                let mut g = m.lock().unwrap();
                *g = true;
                cv.notify_one();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            t.join().unwrap();
        });
    }

    #[test]
    fn model_preemption_bound_zero_runs_threads_sequentially() {
        // With no preemptions allowed, the only switches happen at
        // blocking points — a two-thread program has exactly one
        // schedule.
        let n = Builder {
            preemption_bound: 0,
            max_iterations: 100,
            max_steps: 10_000,
        }
        .check(|| {
            let a = StdArc::new(AtomicUsize::new(0));
            let a2 = StdArc::clone(&a);
            let t = thread::spawn(move || {
                a2.fetch_add(1, SeqCst);
            });
            a.fetch_add(1, SeqCst);
            t.join().unwrap();
            assert_eq!(a.load(SeqCst), 2);
        });
        assert_eq!(n, 1);
    }

    #[test]
    fn model_panic_in_spawned_thread_is_delivered_at_join() {
        small().check(|| {
            let t = thread::spawn(|| panic!("boom"));
            let r = t.join();
            assert!(r.is_err(), "panic payload must reach join");
        });
    }

    #[test]
    fn model_reports_leaked_threads() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            Builder {
                preemption_bound: 0,
                max_iterations: 100,
                max_steps: 10_000,
            }
            .check(|| {
                // Spawn and never join: the execution must be rejected.
                let _t = thread::spawn(|| {});
            });
        }));
        assert!(r.is_err(), "leaked threads must fail the execution");
    }
}
