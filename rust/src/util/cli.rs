//! Tiny command-line parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options with [`Args::flag`] / [`Args::opt`] /
//! typed getters; `--help` output is assembled from those declarations.

use std::collections::BTreeMap;

/// Parsed command line plus accumulated help text.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    /// key -> values (repeated options collect)
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
    help: Vec<(String, String)>,
    about: String,
}

impl Args {
    /// Parse from `std::env::args()`.
    pub fn from_env(about: &str) -> Args {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_else(|| "mec".into());
        Args::parse(program, it.collect(), about)
    }

    /// Parse from an explicit vector (testable).
    pub fn parse(program: String, argv: Vec<String>, about: &str) -> Args {
        let mut opts: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.entry(rest.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(rest.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args {
            program,
            opts,
            flags,
            positional,
            help: Vec::new(),
            about: about.to_string(),
        }
    }

    /// Declare + read a boolean flag.
    pub fn flag(&mut self, name: &str, help: &str) -> bool {
        self.help.push((format!("--{name}"), help.to_string()));
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    /// Declare + read a string option with default.
    pub fn opt(&mut self, name: &str, default: &str, help: &str) -> String {
        self.help
            .push((format!("--{name} <v>"), format!("{help} [default: {default}]")));
        self.opts
            .get(name)
            .and_then(|v| v.last().cloned())
            .unwrap_or_else(|| default.to_string())
    }

    /// Declare + read an optional string option (no default).
    pub fn opt_maybe(&mut self, name: &str, help: &str) -> Option<String> {
        self.help.push((format!("--{name} <v>"), help.to_string()));
        self.opts.get(name).and_then(|v| v.last().cloned())
    }

    /// Declare + read a usize option with default.
    pub fn opt_usize(&mut self, name: &str, default: usize, help: &str) -> usize {
        let raw = self.opt(name, &default.to_string(), help);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("error: --{name} expects an integer, got {raw:?}");
            std::process::exit(2);
        })
    }

    /// Declare + read an f64 option with default.
    pub fn opt_f64(&mut self, name: &str, default: f64, help: &str) -> f64 {
        let raw = self.opt(name, &default.to_string(), help);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("error: --{name} expects a number, got {raw:?}");
            std::process::exit(2);
        })
    }

    /// All values given for a repeatable option.
    pub fn opt_all(&mut self, name: &str, help: &str) -> Vec<String> {
        self.help
            .push((format!("--{name} <v> (repeatable)"), help.to_string()));
        self.opts.get(name).cloned().unwrap_or_default()
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (subcommand style).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Render help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nUsage: {} [options]\n\nOptions:\n", self.about, self.program);
        for (k, h) in &self.help {
            s.push_str(&format!("  {k:<28} {h}\n"));
        }
        s.push_str("  --help                       show this message\n");
        s
    }

    /// If `--help` was passed, print usage and exit. Call after declaring
    /// all options so the help is complete.
    pub fn finish(&self) {
        if self.flags.iter().any(|f| f == "help") {
            println!("{}", self.usage());
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(argv: &[&str]) -> Args {
        Args::parse(
            "test".into(),
            argv.iter().map(|s| s.to_string()).collect(),
            "about",
        )
    }

    #[test]
    fn parses_key_value() {
        let mut a = mk(&["--layers", "cv1", "--batch", "32"]);
        assert_eq!(a.opt("layers", "all", ""), "cv1");
        assert_eq!(a.opt_usize("batch", 1, ""), 32);
    }

    #[test]
    fn parses_key_eq_value() {
        let mut a = mk(&["--batch=8"]);
        assert_eq!(a.opt_usize("batch", 1, ""), 8);
    }

    #[test]
    fn parses_flags() {
        // NOTE: subcommands go first — `--flag value`-style ambiguity is
        // resolved in favour of options (documented parser behaviour).
        let mut a = mk(&["run", "--verbose"]);
        assert!(a.flag("verbose", ""));
        assert!(!a.flag("quiet", ""));
        assert_eq!(a.subcommand(), Some("run"));
    }

    #[test]
    fn defaults_apply() {
        let mut a = mk(&[]);
        assert_eq!(a.opt("algo", "mec", ""), "mec");
        assert_eq!(a.opt_usize("threads", 4, ""), 4);
        assert!(a.opt_maybe("missing", "").is_none());
    }

    #[test]
    fn repeated_options_collect() {
        let mut a = mk(&["--layer", "cv1", "--layer", "cv2"]);
        assert_eq!(a.opt_all("layer", ""), vec!["cv1", "cv2"]);
    }

    #[test]
    fn usage_mentions_declared() {
        let mut a = mk(&[]);
        let _ = a.opt("algo", "mec", "algorithm to use");
        assert!(a.usage().contains("--algo"));
        assert!(a.usage().contains("algorithm to use"));
    }

    #[test]
    fn last_value_wins() {
        let mut a = mk(&["--batch", "8", "--batch", "16"]);
        assert_eq!(a.opt_usize("batch", 1, ""), 16);
    }
}
