//! Minimal property-based-testing harness.
//!
//! `proptest` is not in the offline registry, so we provide the subset the
//! repo needs: run a property over many generated cases, report the seed and
//! the generated case on failure, and optionally shrink integer tuples by
//! halving toward the minimum. Deterministic by default (fixed seed) so CI
//! is stable; override via `MEC_PROP_SEED` / `MEC_PROP_CASES`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("MEC_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        let cases = std::env::var("MEC_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config { cases, seed }
    }
}

/// ASCII "MEC_SEED" — fixed default so CI runs are reproducible.
pub const DEFAULT_SEED: u64 = 0x4d45_435f_5345_4544;

/// Run `prop` on `cfg.cases` cases produced by `gen`. Panics with the seed,
/// case index, and debug-printed input on the first failure (after trying
/// to shrink via `shrink`).
pub fn check_with<T, G, P, S>(cfg: &Config, mut gen: G, mut prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let mut case_rng = rng.split();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first shrunken candidate
            // that still fails, up to a bounded number of steps.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        steps += 1;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={:#x}, case {}/{}):\n  input (shrunk): {:?}\n  error: {}",
                cfg.seed, case_idx, cfg.cases, best, best_msg
            );
        }
    }
}

/// `check_with` without shrinking.
pub fn check<T, G, P>(cfg: &Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check_with(cfg, gen, prop, |_| Vec::new());
}

/// Convenience: default config.
pub fn quickcheck<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(&Config::default(), gen, prop);
}

/// Shrinker for a vector of usizes toward provided minimums: yields
/// candidates with each coordinate halved toward its floor.
pub fn shrink_usizes(xs: &[usize], floors: &[usize]) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for i in 0..xs.len() {
        let fl = floors.get(i).copied().unwrap_or(0);
        if xs[i] > fl {
            let mut c = xs.to_vec();
            c[i] = fl + (xs[i] - fl) / 2;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck(
            |r: &mut Rng| r.range(0, 100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        quickcheck(
            |r: &mut Rng| r.range(0, 100),
            |&x| {
                if x < 1 {
                    Ok(())
                } else {
                    Err("nope".into())
                }
            },
        );
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // Property "x < 10" fails for x >= 10; shrinking should land near 10.
        let cfg = Config { cases: 64, seed: 1 };
        let result = std::panic::catch_unwind(|| {
            check_with(
                &cfg,
                |r: &mut Rng| vec![r.range(0, 1000)],
                |xs| {
                    if xs[0] < 10 {
                        Ok(())
                    } else {
                        Err("too big".into())
                    }
                },
                |xs| shrink_usizes(xs, &[0]),
            );
        });
        let err = result.expect_err("should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // Greedy halving from anywhere in [10,1000) must end in [10, 20).
        let shrunk: usize = msg
            .split('[')
            .nth(1)
            .and_then(|s| s.split(']').next())
            .and_then(|s| s.trim().parse().ok())
            .expect("parse shrunk value");
        assert!((10..20).contains(&shrunk), "shrunk to {shrunk}: {msg}");
    }

    #[test]
    fn default_seed_is_stable() {
        assert_eq!(DEFAULT_SEED, 0x4d45_435f_5345_4544);
    }

    #[test]
    fn shrink_usizes_respects_floors() {
        let cands = shrink_usizes(&[8, 3], &[2, 3]);
        assert_eq!(cands, vec![vec![5, 3]]);
    }
}
