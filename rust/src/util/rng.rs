//! Deterministic pseudo-random number generation.
//!
//! The whole repository (tests, property harness, benchmarks, synthetic
//! workloads) must be reproducible offline, so we ship our own small PRNG
//! instead of depending on the `rand` crate (unavailable in the vendored
//! registry). `SplitMix64` is statistically strong for this purpose, has a
//! one-word state, and is trivially splittable for parallel streams.

/// SplitMix64 PRNG (Steele, Lea & Flood, OOPSLA'14).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams on every platform.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream (for parallel workers / sub-generators).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses the widening-multiply trick (Lemire) to
    /// avoid modulo bias for the magnitudes used here.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa-ish bits -> exact representable grid in [0,1).
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard-normal-ish value via the sum of 4 uniforms (Irwin–Hall,
    /// variance-corrected). Good enough for weight init & synthetic data;
    /// avoids transcendental functions in the hot test path.
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.f32()).sum::<f32>() - 2.0;
        s * (12.0f32 / 4.0).sqrt()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.range(5, 10);
            assert!((5..10).contains(&v));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
