//! Small statistics helpers shared by the bench harness and the
//! coordinator's latency metrics (criterion is unavailable offline, so the
//! bench harness is ours — see `bench::harness`).

/// Summary statistics over a sample of measurements (e.g. nanoseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty sample.
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from(empty)");
        let mut xs: Vec<f64> = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&xs, 50.0);
        let mut devs: Vec<f64> = xs.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0);
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        Summary {
            n,
            min: xs[0],
            max: xs[n - 1],
            mean,
            median,
            mad,
            p95: percentile_sorted(&xs, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of a **sorted** slice. `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Streaming histogram with fixed power-of-two-ish bucket boundaries,
/// used by the coordinator for latency percentiles without storing every
/// sample. Buckets grow geometrically from `base_ns`.
#[derive(Debug, Clone)]
pub struct Histogram {
    base: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    /// `base`: upper bound of the first bucket; `growth`: geometric factor;
    /// `buckets`: number of buckets (everything above the last bound lands
    /// in the overflow bucket).
    pub fn new(base: f64, growth: f64, buckets: usize) -> Histogram {
        assert!(base > 0.0 && growth > 1.0 && buckets >= 2);
        Histogram {
            base,
            growth,
            counts: vec![0; buckets],
            total: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    /// Default latency histogram: 1µs..~70s in 64 buckets (ns units).
    pub fn latency_ns() -> Histogram {
        Histogram::new(1_000.0, 1.33, 64)
    }

    fn bucket_of(&self, v: f64) -> usize {
        let mut bound = self.base;
        for i in 0..self.counts.len() - 1 {
            if v <= bound {
                return i;
            }
            bound *= self.growth;
        }
        self.counts.len() - 1
    }

    pub fn record(&mut self, v: f64) {
        let b = self.bucket_of(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile: upper bound of the bucket containing the
    /// p-th sample. `p` in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        let mut bound = self.base;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == self.counts.len() - 1 { self.max } else { bound };
            }
            bound *= self.growth;
        }
        self.max
    }

    /// Merge another histogram with identical shape.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Human-readable byte count ("41.7 MB" style, decimal like the paper).
pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = Histogram::latency_ns();
        for i in 1..=1000 {
            h.record(i as f64 * 10_000.0); // 10µs..10ms
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 should be around 5ms (5e6 ns) within a bucket factor.
        assert!(p50 > 2e6 && p50 < 12e6, "p50={p50}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::latency_ns();
        let mut b = Histogram::latency_ns();
        a.record(1e6);
        b.record(2e6);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 1.5e6).abs() < 1.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(500), "500 B");
        assert_eq!(fmt_bytes(41_700_000), "41.7 MB");
        assert_eq!(fmt_ns(1_500_000.0), "1.50 ms");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::latency_ns();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
