//! Minimal error-handling substrate (anyhow is unavailable offline).
//!
//! Provides the subset the runtime layer needs: a string-backed [`Error`]
//! type, a [`Result`] alias, a [`Context`] extension trait mirroring
//! `anyhow::Context`, and `bail!` / `ensure!` macros. Everything else in
//! the repository uses concrete error enums; this is only for the
//! "many things can go wrong, report a readable chain" paths (artifact
//! loading, PJRT execution, examples).

use std::fmt;

/// A human-readable error, optionally carrying the message chain built up
/// by [`Context::context`].
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e.to_string())
    }
}

/// Result alias used by the runtime layer.
pub type Result<T> = std::result::Result<T, Error>;

/// `anyhow::Context`-style message chaining for any displayable error.
pub trait Context<T> {
    /// Wrap the error with `msg: <original>`.
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    /// Like [`Context::context`], but the message is computed lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| Error::new(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::new(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::new(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Construct an [`Error`] from a format string (expression position).
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("opening manifest").unwrap_err();
        assert!(e.to_string().contains("opening manifest"));
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing artifact").unwrap_err();
        assert_eq!(e.to_string(), "missing artifact");
        assert_eq!(Some(7).context("fine").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).unwrap_err().to_string().contains("three"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }
}
