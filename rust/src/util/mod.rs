//! Cross-cutting utilities: deterministic RNG, property-testing harness,
//! statistics, CLI parsing, logging. All substrates the offline build
//! cannot pull from crates.io (rand/proptest/clap/env_logger/criterion).

// Utilities stay on safe Rust: no unsafe, ever (enforced — see the
// crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod cli;
pub mod error;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;

/// Relative-L2 + max-abs comparison used everywhere we check numerics
/// between two convolution implementations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diff {
    pub max_abs: f64,
    pub rel_l2: f64,
}

/// Compare two equally-shaped buffers.
pub fn diff(a: &[f32], b: &[f32]) -> Diff {
    assert_eq!(a.len(), b.len(), "diff: length mismatch {} vs {}", a.len(), b.len());
    let mut max_abs = 0f64;
    let mut num = 0f64;
    let mut den = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x as f64 - y as f64).abs();
        if d > max_abs {
            max_abs = d;
        }
        num += (x as f64 - y as f64).powi(2);
        den += (y as f64).powi(2);
    }
    Diff {
        max_abs,
        rel_l2: if den == 0.0 { num.sqrt() } else { (num / den).sqrt() },
    }
}

/// Assert two buffers match within tolerances, with a helpful message.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f64, context: &str) {
    let d = diff(a, b);
    assert!(
        d.rel_l2 <= rtol,
        "{context}: buffers differ: rel_l2={:.3e} (rtol={rtol:.1e}), max_abs={:.3e}",
        d.rel_l2,
        d.max_abs
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_identical_is_zero() {
        let a = [1.0f32, -2.0, 3.5];
        let d = diff(&a, &a);
        assert_eq!(d.max_abs, 0.0);
        assert_eq!(d.rel_l2, 0.0);
    }

    #[test]
    fn diff_detects_mismatch() {
        let a = [1.0f32, 2.0];
        let b = [1.0f32, 2.5];
        let d = diff(&a, &b);
        assert!(d.max_abs > 0.49 && d.max_abs < 0.51);
    }

    #[test]
    #[should_panic(expected = "buffers differ")]
    fn assert_allclose_panics() {
        assert_allclose(&[1.0], &[2.0], 1e-6, "test");
    }
}
