//! Minimal leveled logging (the `log`/`env_logger` crates are unavailable
//! offline).
//!
//! `MEC_LOG=trace|debug|info|warn|error|off` controls verbosity; default
//! `info`. Output goes to stderr with a monotonic timestamp so serving
//! traces line up with latency measurements. Use via the crate-root
//! macros: `mec::log_info!("...")`, `mec::log_warn!("...")`, etc.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// 0 = off; otherwise the numeric value of the maximum enabled [`Level`].
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Install the logger (idempotent). Reads `MEC_LOG`.
pub fn init() {
    START.get_or_init(Instant::now);
    let level = match std::env::var("MEC_LOG").as_deref() {
        Ok("trace") => Level::Trace as u8,
        Ok("debug") => Level::Debug as u8,
        Ok("warn") => Level::Warn as u8,
        Ok("error") => Level::Error as u8,
        Ok("off") => 0,
        _ => Level::Info as u8,
    };
    MAX_LEVEL.store(level, Ordering::Relaxed);
}

/// Whether a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit a log record. Prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        t.as_secs_f64(),
        level.label(),
        target,
        args
    );
}

/// Log at ERROR level.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at WARN level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at INFO level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at DEBUG level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logging smoke test");
    }

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn off_disables_everything() {
        // Not testing env parsing here (process-global); just the gate.
        let prev = MAX_LEVEL.swap(0, Ordering::Relaxed);
        assert!(!enabled(Level::Error));
        MAX_LEVEL.store(prev, Ordering::Relaxed);
    }
}
