//! Minimal `log` facade backend (env_logger is unavailable offline).
//!
//! `MEC_LOG=debug|info|warn|error|off` controls verbosity; default `info`.
//! Output goes to stderr with a monotonic timestamp so serving traces line
//! up with latency measurements.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::Once;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Reads `MEC_LOG`.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("MEC_LOG").as_deref() {
            Ok("trace") => LevelFilter::Trace,
            Ok("debug") => LevelFilter::Debug,
            Ok("warn") => LevelFilter::Warn,
            Ok("error") => LevelFilter::Error,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger {
            start: Instant::now(),
            level,
        });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
