//! Figure 4(c): runtime on Mobile (1 thread, batch 1) across cv1–cv12
//! for Conv.cpu, Wino.cpu (3×3 layers), and MEC.cpu.
//!
//! Paper's claims: MEC.cpu ~20% faster than Conv.cpu overall, up to
//! ~90% on cv6; faster than Wino.cpu on 5 of the 7 3×3 layers.
//! `MEC_BENCH_SCALE` shrinks channels for quick runs (default: paper
//! scale — the big early layers take a few hundred ms each on 1 thread).

use mec::bench::bench_conv;
use mec::bench::harness::{bench_mode, bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale();
    let ctx = ConvContext::mobile();
    let opts = BenchOpts::default();
    let mut rng = Rng::new(43);
    let mut rows = Vec::new();
    let mut sums = [0.0f64; 3];
    println!("Figure 4(c) reproduction: Mobile (1 thread, batch 1), scale={scale}");
    println!("timing mode: {}", bench_mode().label());
    for w in suite() {
        let shape = w.shape(1, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut cells = vec![w.name.to_string()];
        let mut layer_ms = [f64::NAN; 3];
        for (i, kind) in [AlgoKind::Im2col, AlgoKind::WinogradChunked, AlgoKind::Mec]
            .iter()
            .enumerate()
        {
            let algo = kind.build();
            if !algo.supports(&shape) {
                cells.push("-".into());
                continue;
            }
            let name = format!("{}-{}", w.name, algo.name());
            let r = bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            layer_ms[i] = r.median_ms();
            sums[i] += r.median_ms();
            cells.push(format!("{:.1}", r.median_ms()));
        }
        cells.push(if layer_ms[2].is_finite() && layer_ms[0].is_finite() {
            format!("{:.2}x", layer_ms[0] / layer_ms[2])
        } else {
            "-".into()
        });
        rows.push(cells);
    }
    print_table(
        "Fig 4c — runtime (ms), Mobile",
        &["layer", "Conv.cpu", "Wino.cpu", "MEC.cpu", "conv/mec"],
        &rows,
    );
    println!(
        "\ntotals: Conv.cpu {:.0} ms | Wino.cpu {:.0} ms (3x3 only) | MEC.cpu {:.0} ms  => overall MEC speedup {:.2}x (paper: ~1.2x)",
        sums[0],
        sums[1],
        sums[2],
        sums[0] / sums[2]
    );
}
