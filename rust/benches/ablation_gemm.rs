//! Ablation: GEMM cache-blocking parameters on MEC's hot shapes.
//!
//! MEC funnels its FLOPs through many (m = i_n·o_w) × (k_h·k_w·i_c) ×
//! k_c gemms; this sweeps MC/KC/NC on cv6 and cv4 geometry to justify
//! the defaults (DESIGN.md §9).

use mec::bench::bench_conv;
use mec::bench::harness::{bench_scale, print_table, BenchOpts};
use mec::bench::workload::by_name;
use mec::conv::{AlgoKind, ConvContext};
use mec::gemm::BlockSizes;
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale().max(2);
    let opts = BenchOpts::default();
    let mut rng = Rng::new(9);
    let candidates = [
        BlockSizes { mc: 32, kc: 64, nc: 128 },
        BlockSizes { mc: 64, kc: 128, nc: 256 },
        BlockSizes { mc: 128, kc: 256, nc: 512 }, // default
        BlockSizes { mc: 256, kc: 256, nc: 512 },
        BlockSizes { mc: 128, kc: 512, nc: 256 },
        BlockSizes { mc: 64, kc: 256, nc: 1024 },
    ];
    let mut rows = Vec::new();
    for name in ["cv6", "cv4", "cv11"] {
        let shape = by_name(name).unwrap().shape(1, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut cells = vec![name.to_string()];
        let mut best = (f64::INFINITY, 0usize);
        for (i, bs) in candidates.iter().enumerate() {
            let mut ctx = ConvContext::mobile();
            ctx.blocks = *bs;
            let algo = AlgoKind::Mec.build();
            let bname = format!("{name}-bs{i}");
            let r = bench_conv(&bname, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            if r.median_ns() < best.0 {
                best = (r.median_ns(), i);
            }
            cells.push(format!("{:.1}", r.median_ms()));
        }
        cells.push(format!(
            "mc{}/kc{}/nc{}",
            candidates[best.1].mc, candidates[best.1].kc, candidates[best.1].nc
        ));
        rows.push(cells);
    }
    let header: Vec<String> = std::iter::once("layer".into())
        .chain(
            candidates
                .iter()
                .map(|b| format!("{}·{}·{}", b.mc, b.kc, b.nc)),
        )
        .chain(std::iter::once("best".into()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table("Ablation — MEC runtime (ms) vs GEMM blocking (MC·KC·NC)", &header_refs, &rows);
}
