//! Figure 4(d): runtime on Server-CPU (all cores, mini-batch 32) across
//! cv1–cv12 for Conv.cpu, Wino.cpu, and MEC.cpu.
//!
//! Paper's claim: MEC.cpu ~8.8× faster than Conv.cpu overall (their
//! many-core Xeon punished im2col's footprint; on this host the *sign*
//! — MEC ≥ Conv — is the reproduction target). Default batch is scaled
//! to 8 (32 × cv4's im2col workspace is 4.8 GB and dominates wall time
//! on 1 core); set MEC_BENCH_BATCH=32 for the paper's batch.

use mec::bench::bench_conv;
use mec::bench::harness::{bench_mode, bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale().max(2); // server sweep default: /2 channels
    let batch: usize = std::env::var("MEC_BENCH_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let ctx = ConvContext::server();
    let opts = BenchOpts::default();
    let mut rng = Rng::new(44);
    let mut rows = Vec::new();
    let mut sums = [0.0f64; 3];
    println!(
        "Figure 4(d) reproduction: Server-CPU ({} threads), batch={batch}, scale={scale}",
        ctx.threads()
    );
    println!("timing mode: {}", bench_mode().label());
    for w in suite() {
        let shape = w.shape(batch, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut cells = vec![w.name.to_string()];
        let mut layer_ms = [f64::NAN; 3];
        for (i, kind) in [AlgoKind::Im2col, AlgoKind::Winograd, AlgoKind::Mec]
            .iter()
            .enumerate()
        {
            let algo = kind.build();
            if !algo.supports(&shape) {
                cells.push("-".into());
                continue;
            }
            let name = format!("{}-{}", w.name, algo.name());
            let r = bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            layer_ms[i] = r.median_ms();
            sums[i] += r.median_ms();
            cells.push(format!("{:.1}", r.median_ms()));
        }
        cells.push(if layer_ms[2].is_finite() && layer_ms[0].is_finite() {
            format!("{:.2}x", layer_ms[0] / layer_ms[2])
        } else {
            "-".into()
        });
        rows.push(cells);
    }
    print_table(
        "Fig 4d — runtime (ms), Server-CPU",
        &["layer", "Conv.cpu", "Wino.cpu", "MEC.cpu", "conv/mec"],
        &rows,
    );
    println!(
        "\ntotals: Conv.cpu {:.0} ms | MEC.cpu {:.0} ms => overall speedup {:.2}x (paper: 8.8x on 2-socket Xeon; expect smaller on this host)",
        sums[0],
        sums[2],
        sums[0] / sums[2]
    );
}
