//! Figure 4(f): runtime on Server-GPU across cv1–cv12 — Conv.gpu,
//! Wino.gpu, FFT.gpu, MEC.gpu.
//!
//! GPU substitution (DESIGN.md §6): no GPU on this host. The same rust
//! engine runs in gpu-sim mode (batched-gemm path = the structure
//! `cublasSgemmBatched` executes). Two of the paper's claims survive the
//! substitution because they are byte-traffic facts, which we measure:
//!
//! * "MEC.gpu lowers the matrix about 85% faster than Conv.gpu due to
//!   much fewer bytes to write" — we time the *lowering loops only*
//!   (also see `ablation_lowering`), and compare bytes written.
//! * Relative end-to-end ordering on the small-kernel layers.
//!
//! FFT runtimes are only taken on the layers where the paper-faithful
//! spectra fit the cache cap (cv5/cv6/cv11/cv12-class); FFT's *memory*
//! story is Fig 4e.

use mec::bench::bench_conv;
use mec::bench::harness::{bench_fn, bench_mode, bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::im2col::Im2col;
use mec::conv::mec::Mec;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale().max(2);
    let batch: usize = std::env::var("MEC_BENCH_BATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let ctx = ConvContext::server();
    let opts = BenchOpts::default();
    let mut rng = Rng::new(46);
    println!(
        "Figure 4(f) reproduction: Server-GPU(sim) = batched-gemm engine, batch={batch}, scale={scale}"
    );
    println!("timing mode: {}", bench_mode().label());

    // Part 1: lowering-only — bytes written + time (the 85% claim).
    let mut rows = Vec::new();
    for w in suite() {
        let shape = w.shape(batch, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let i2c_elems = shape.im2col_lowered_elems();
        let mec_elems = shape.mec_lowered_elems();
        let mut l1 = vec![0.0f32; i2c_elems];
        let mut l2 = vec![0.0f32; mec_elems];
        let r1 = bench_fn(&format!("{}-i2c-lower", w.name), &opts, || {
            Im2col::lower(&ctx, &shape, &input, &mut l1);
        });
        let r2 = bench_fn(&format!("{}-mec-lower", w.name), &opts, || {
            Mec::lower(&ctx, &shape, &input, &mut l2);
        });
        rows.push(vec![
            w.name.to_string(),
            format!("{:.1}", i2c_elems as f64 * 4.0 / 1e6),
            format!("{:.1}", mec_elems as f64 * 4.0 / 1e6),
            format!("{:.2}", r1.median_ms()),
            format!("{:.2}", r2.median_ms()),
            format!("{:.0}%", 100.0 * (1.0 - r2.median_ns() / r1.median_ns())),
        ]);
    }
    print_table(
        "Fig 4f part 1 — lowering only: bytes written + time (paper: MEC ~85% faster)",
        &["layer", "i2c MB", "mec MB", "i2c ms", "mec ms", "mec faster by"],
        &rows,
    );

    // Part 2: end-to-end with the batched path (gpu-sim).
    let mut rows = Vec::new();
    for w in suite() {
        let shape = w.shape(batch, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut cells = vec![w.name.to_string()];
        for kind in [AlgoKind::Im2col, AlgoKind::Winograd, AlgoKind::Fft, AlgoKind::MecSolutionB] {
            let algo = kind.build();
            let skip_fft = kind == AlgoKind::Fft
                && Convolution::workspace_bytes(&*algo, &shape) > ctx.fft_cache_cap_bytes;
            if !algo.supports(&shape) || skip_fft {
                cells.push("-".into());
                continue;
            }
            let name = format!("{}-{}", w.name, algo.name());
            let r = bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            cells.push(format!("{:.1}", r.median_ms()));
        }
        rows.push(cells);
    }
    print_table(
        "Fig 4f part 2 — end-to-end runtime (ms), gpu-sim (host CPU stand-in)",
        &["layer", "Conv", "Wino", "FFT", "MEC(B)"],
        &rows,
    );
    println!("\nFFT cells '-' = paper-model spectra exceed the cache cap on this host (memory story in fig4e).");
}
