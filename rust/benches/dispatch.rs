//! Dispatch microbench: what one parallel loop costs on each substrate —
//! the persistent parked pool, the old spawn-per-call scoped baseline,
//! and a plain inline loop — across grain sizes from "far too small to
//! parallelize" to "clearly worth it".
//!
//! This is the measurement behind the pool refactor: MEC's per-row GEMMs
//! (Solution B issues `i_n·o_h` of them) put tens of microseconds of work
//! behind every dispatch, so the spawn+join cost of `std::thread::scope`
//! dominated at exactly the sizes the paper cares about. Expected shape:
//! pool dispatch is several times cheaper than scoped spawn at small
//! grains and converges with it as the body grows; inline wins below the
//! grain cutoff, which is why `Parallelism`'s cost-model heuristic
//! exists.
//!
//! Run: `cargo bench --bench dispatch`
//! (env: MEC_THREADS pins the width, MEC_BENCH_FAST caps reps)

use mec::bench::harness::{bench_fn, bench_threads, print_table, threads_label, BenchOpts};
use mec::threadpool::{os_threads_spawned, scoped_parallel_for, Parallelism};
use std::hint::black_box;

/// A compute body of tunable size (~`work` FMAs), opaque to the
/// optimizer.
fn busy(work: usize, seed: usize) -> f32 {
    let mut acc = seed as f32 * 0.001;
    for i in 0..work {
        acc = acc.mul_add(0.999_9, (i & 7) as f32 * 0.125);
    }
    acc
}

fn main() {
    let threads = bench_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let par = Parallelism::new(threads);
    let opts = BenchOpts::default();
    println!(
        "Dispatch microbench: pool vs scoped-spawn vs inline, {}",
        threads_label(threads)
    );
    println!(
        "pool: {} parked workers (spawned once); scoped: {} spawns per loop",
        par.pool().map(|p| p.workers()).unwrap_or(0),
        threads
    );

    // (items, FMAs per item): spans MEC's tiny o_w-row GEMMs (first rows)
    // up to comfortably-parallel bodies (last rows).
    let grains: &[(usize, usize)] = &[
        (8, 100),
        (64, 100),
        (64, 1_000),
        (256, 1_000),
        (256, 10_000),
        (1024, 10_000),
    ];

    let mut rows = Vec::new();
    let mut small_grain_ratio = None;
    for &(n, work) in grains {
        let inline = bench_fn(&format!("inline-{n}x{work}"), &opts, || {
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += busy(work, i);
            }
            black_box(acc);
        });
        // `parallel_for` (not the grained variant): measures raw pool
        // dispatch even below the cutoff the production paths would
        // inline at.
        let pool = bench_fn(&format!("pool-{n}x{work}"), &opts, || {
            par.parallel_for(n, |i| {
                black_box(busy(work, i));
            });
        });
        let scoped = bench_fn(&format!("scoped-{n}x{work}"), &opts, || {
            scoped_parallel_for(threads, n, |i| {
                black_box(busy(work, i));
            });
        });
        // Dispatch overhead proxy at the smallest grain: scoped / pool.
        if small_grain_ratio.is_none() {
            small_grain_ratio = Some(scoped.median_ns() / pool.median_ns().max(1.0));
        }
        rows.push(vec![
            n.to_string(),
            work.to_string(),
            format!("{:.1}", inline.median_ns() / 1e3),
            format!("{:.1}", pool.median_ns() / 1e3),
            format!("{:.1}", scoped.median_ns() / 1e3),
            format!("{:.2}", scoped.median_ns() / pool.median_ns().max(1.0)),
            if par.should_inline((n * work) as f64 * par.grain().ns_per_mac) {
                "inline".to_string()
            } else {
                "pool".to_string()
            },
        ]);
    }
    print_table(
        "Dispatch cost by grain (µs median)",
        &["items", "work/item", "inline µs", "pool µs", "scoped µs", "scoped/pool", "heuristic"],
        &rows,
    );
    println!(
        "\nsmallest-grain dispatch advantage (scoped / pool): {:.1}x \
         (acceptance target: >= 5x)",
        small_grain_ratio.unwrap_or(f64::NAN)
    );
    println!(
        "OS threads spawned this run: {} (pool workers once + scoped baseline per loop)",
        os_threads_spawned()
    );
}
