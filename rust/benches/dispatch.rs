//! Dispatch microbench: what one parallel loop costs on each substrate —
//! the persistent parked pool, the old spawn-per-call scoped baseline,
//! and a plain inline loop — across grain sizes from "far too small to
//! parallelize" to "clearly worth it".
//!
//! This is the measurement behind the pool refactor: MEC's per-row GEMMs
//! (Solution B issues `i_n·o_h` of them) put tens of microseconds of work
//! behind every dispatch, so the spawn+join cost of `std::thread::scope`
//! dominated at exactly the sizes the paper cares about. Expected shape:
//! pool dispatch is several times cheaper than scoped spawn at small
//! grains and converges with it as the body grows; inline wins below the
//! grain cutoff, which is why `Parallelism`'s cost-model heuristic
//! exists.
//!
//! A second section sweeps the GEMM micro-kernel backends (scalar and
//! every SIMD variant this host supports, × f32/q16) at an L2-resident
//! size and records GFLOP/s to `BENCH_gemm.json` — the seed point for
//! the kernel-dispatch perf trajectory.
//!
//! Run: `cargo bench --bench dispatch`
//! (env: MEC_THREADS pins the width, MEC_BENCH_FAST caps reps)

use mec::bench::harness::{
    bench_fn, bench_threads, kernel_label, print_table, threads_label, BenchOpts,
};
use mec::gemm::{
    gemm_prepacked, gemm_prepacked_i16, BlockSizes, KernelBackend, MatMut, MatRef, MatRefI16,
    PackedB, PackedBI16, Q16Epilogue,
};
use mec::threadpool::{os_threads_spawned, scoped_parallel_for, Parallelism};
use mec::util::Rng;
use std::hint::black_box;

/// One backend × precision GEMM measurement at the L2-resident size.
struct GemmRow {
    backend: KernelBackend,
    precision: &'static str,
    median_ns: f64,
    gflops: f64,
}

/// Time `m×k · k×n` on every detected backend in both precisions,
/// single-threaded (isolates kernel throughput from pool dispatch —
/// the first table already covers dispatch).
fn gemm_backend_sweep(m: usize, k: usize, n: usize, opts: &BenchOpts) -> Vec<GemmRow> {
    let flops = 2.0 * (m * k * n) as f64;
    let mut rng = Rng::new(0x6ec);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    // Q15 operands from the same values (unit scale keeps it simple —
    // throughput, not accuracy, is under test here).
    let ai: Vec<i16> = a.iter().map(|&v| (v * 16384.0) as i16).collect();
    let bi: Vec<i16> = b.iter().map(|&v| (v * 16384.0) as i16).collect();
    let mut c = vec![0.0f32; m * n];
    let bs = BlockSizes::default();
    let mut rows = Vec::new();
    for backend in KernelBackend::all_available() {
        // Prepacked B carries the backend: the dispatch follows the pack,
        // not the process-wide active() choice, so each backend is
        // measurable regardless of MEC_KERNEL.
        let pb = PackedB::pack_with(MatRef::new(&b, k, n), bs, backend);
        let r = bench_fn(&format!("gemm-f32-{}", backend.name()), opts, || {
            let av = MatRef::new(&a, m, k);
            let mut cv = MatMut::new(&mut c, m, n);
            gemm_prepacked(av, &pb, &mut cv);
            black_box(cv.at(0, 0));
        });
        rows.push(GemmRow {
            backend,
            precision: "f32",
            median_ns: r.median_ns(),
            gflops: flops / r.median_ns(),
        });
        let pbq = PackedBI16::pack_with(MatRefI16::new(&bi, k, n), bs, backend);
        let ep = Q16Epilogue::uniform(1.0 / (16384.0 * 16384.0));
        let r = bench_fn(&format!("gemm-q16-{}", backend.name()), opts, || {
            let av = MatRefI16::new(&ai, m, k);
            let mut cv = MatMut::new(&mut c, m, n);
            gemm_prepacked_i16(av, &pbq, &mut cv, ep);
            black_box(cv.at(0, 0));
        });
        rows.push(GemmRow {
            backend,
            precision: "q16",
            median_ns: r.median_ns(),
            gflops: flops / r.median_ns(),
        });
    }
    rows
}

/// A compute body of tunable size (~`work` FMAs), opaque to the
/// optimizer.
fn busy(work: usize, seed: usize) -> f32 {
    let mut acc = seed as f32 * 0.001;
    for i in 0..work {
        acc = acc.mul_add(0.999_9, (i & 7) as f32 * 0.125);
    }
    acc
}

fn main() {
    let threads = bench_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let par = Parallelism::new(threads);
    let opts = BenchOpts::default();
    println!(
        "Dispatch microbench: pool vs scoped-spawn vs inline, {}",
        threads_label(threads)
    );
    println!(
        "pool: {} parked workers (spawned once); scoped: {} spawns per loop",
        par.pool().map(|p| p.workers()).unwrap_or(0),
        threads
    );

    // (items, FMAs per item): spans MEC's tiny o_w-row GEMMs (first rows)
    // up to comfortably-parallel bodies (last rows).
    let grains: &[(usize, usize)] = &[
        (8, 100),
        (64, 100),
        (64, 1_000),
        (256, 1_000),
        (256, 10_000),
        (1024, 10_000),
    ];

    let mut rows = Vec::new();
    let mut small_grain_ratio = None;
    for &(n, work) in grains {
        let inline = bench_fn(&format!("inline-{n}x{work}"), &opts, || {
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += busy(work, i);
            }
            black_box(acc);
        });
        // `parallel_for` (not the grained variant): measures raw pool
        // dispatch even below the cutoff the production paths would
        // inline at.
        let pool = bench_fn(&format!("pool-{n}x{work}"), &opts, || {
            par.parallel_for(n, |i| {
                black_box(busy(work, i));
            });
        });
        let scoped = bench_fn(&format!("scoped-{n}x{work}"), &opts, || {
            scoped_parallel_for(threads, n, |i| {
                black_box(busy(work, i));
            });
        });
        // Dispatch overhead proxy at the smallest grain: scoped / pool.
        if small_grain_ratio.is_none() {
            small_grain_ratio = Some(scoped.median_ns() / pool.median_ns().max(1.0));
        }
        rows.push(vec![
            n.to_string(),
            work.to_string(),
            format!("{:.1}", inline.median_ns() / 1e3),
            format!("{:.1}", pool.median_ns() / 1e3),
            format!("{:.1}", scoped.median_ns() / 1e3),
            format!("{:.2}", scoped.median_ns() / pool.median_ns().max(1.0)),
            if par.should_inline((n * work) as f64 * par.grain().ns_per_mac) {
                "inline".to_string()
            } else {
                "pool".to_string()
            },
        ]);
    }
    print_table(
        "Dispatch cost by grain (µs median)",
        &["items", "work/item", "inline µs", "pool µs", "scoped µs", "scoped/pool", "heuristic"],
        &rows,
    );
    println!(
        "\nsmallest-grain dispatch advantage (scoped / pool): {:.1}x \
         (acceptance target: >= 5x)",
        small_grain_ratio.unwrap_or(f64::NAN)
    );
    println!(
        "OS threads spawned this run: {} (pool workers once + scoped baseline per loop)",
        os_threads_spawned()
    );

    // --- GEMM micro-kernel backends ---------------------------------
    // L2-resident operands: 192³ keeps A+B+C ≈ 430 KB, so the kernel —
    // not memory bandwidth — sets the rate.
    let (m, k, n) = (192, 192, 192);
    println!("\nGEMM backend sweep: {m}x{k}x{n}, 1 thread, active = {}", kernel_label());
    let gemm_rows = gemm_backend_sweep(m, k, n, &opts);
    let scalar_f32 = gemm_rows
        .iter()
        .find(|r| r.backend == KernelBackend::Scalar && r.precision == "f32")
        .map(|r| r.median_ns);
    let table: Vec<Vec<String>> = gemm_rows
        .iter()
        .map(|r| {
            vec![
                r.backend.name().to_string(),
                r.precision.to_string(),
                format!("{}x{}", mec::gemm::micro::MR, r.backend.nr()),
                format!("{:.1}", r.median_ns / 1e3),
                format!("{:.2}", r.gflops),
                match (r.precision, scalar_f32) {
                    ("f32", Some(s)) => format!("{:.2}", s / r.median_ns),
                    _ => "-".to_string(),
                },
            ]
        })
        .collect();
    print_table(
        "GEMM kernel throughput by backend (acceptance: SIMD f32 >= 1.3x scalar)",
        &["backend", "precision", "tile", "µs", "GFLOP/s", "vs scalar"],
        &table,
    );

    // Machine-readable seed point for the perf trajectory.
    let mut json = format!(
        "{{\"bench\":\"gemm\",\"threads\":1,\"m\":{m},\"k\":{k},\"n\":{n},\"results\":["
    );
    for (i, r) in gemm_rows.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"backend\":\"{}\",\"precision\":\"{}\",\"mr\":{},\"nr\":{},\
             \"median_ns\":{:.0},\"gflops\":{:.3}}}",
            r.backend.name(),
            r.precision,
            mec::gemm::micro::MR,
            r.backend.nr(),
            r.median_ns,
            r.gflops
        ));
    }
    json.push_str("]}\n");
    match std::fs::write("BENCH_gemm.json", &json) {
        Ok(()) => println!("\nwrote BENCH_gemm.json"),
        Err(e) => eprintln!("\nwarning: could not write BENCH_gemm.json: {e}"),
    }
}
