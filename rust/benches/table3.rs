//! Table 3: ResNet-101 on Mobile — weighted memory-overhead and runtime
//! for Conv.cpu vs MEC.cpu over the five layer shapes the paper weights
//! {cv4:1, cv9:3, cv10:4, cv11:23, cv12:3}.
//!
//! Paper: Conv.cpu 203.6 MB / 1701.6 ms; MEC.cpu 64.6 MB / 1391.6 ms;
//! ratios 3.2× memory and 1.2× runtime. Memory is exact here; runtime
//! ratio is the shape target (ARM7 vs this host).

use mec::bench::bench_conv;
use mec::bench::harness::{
    bench_mode, bench_precision, bench_scale, bench_threads, kernel_label, print_table,
    threads_label, BenchOpts,
};
use mec::bench::workload::resnet101_table3;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale();
    let mut ctx = ConvContext::mobile().with_precision(bench_precision());
    if let Some(t) = bench_threads() {
        ctx = ctx.with_threads(t);
    }
    let opts = BenchOpts::default();
    let mut rng = Rng::new(101);
    let mut rows = Vec::new();
    let mut tot = [0.0f64; 4]; // conv_mb, conv_ms, mec_mb, mec_ms
    println!(
        "Table 3 reproduction: ResNet-101 weighted conv layers, Mobile ({}), scale={scale}",
        threads_label(ctx.threads())
    );
    println!("timing mode: {}", bench_mode().label());
    println!(
        "precision: {} (set MEC_BENCH_PRECISION=q16 for the paper's fixed-point grid)",
        ctx.precision
    );
    println!("kernel: {}", kernel_label());
    for (w, weight) in resnet101_table3() {
        let shape = w.shape(1, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut vals = [0.0f64; 4];
        for (i, kind) in [AlgoKind::Im2col, AlgoKind::Mec].iter().enumerate() {
            let algo = kind.build();
            let name = format!("{}-{}", w.name, algo.name());
            let r = bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            // Lowering overhead in the run precision: Eq. 2/3 elements ×
            // operand width (q16 halves the paper's MB column).
            vals[i * 2] = (algo.workspace_elems(&shape) * ctx.precision.bytes_per_elem()) as f64
                / 1e6;
            vals[i * 2 + 1] = r.median_ms();
        }
        rows.push(vec![
            w.name.to_string(),
            weight.to_string(),
            format!("{:.1}", vals[0]),
            format!("{:.1}", vals[1]),
            format!("{:.1}", vals[2]),
            format!("{:.1}", vals[3]),
        ]);
        for i in 0..4 {
            tot[i] += weight as f64 * vals[i];
        }
    }
    rows.push(vec![
        "SUM".into(),
        "".into(),
        format!("{:.1}", tot[0]),
        format!("{:.1}", tot[1]),
        format!("{:.1}", tot[2]),
        format!("{:.1}", tot[3]),
    ]);
    rows.push(vec![
        "RATIO".into(),
        "".into(),
        format!("{:.2}", tot[0] / tot[2]),
        format!("{:.2}", tot[1] / tot[3]),
        "1.0".into(),
        "1.0".into(),
    ]);
    print_table(
        "Table 3 — ResNet-101 on Mobile: Conv.cpu vs MEC.cpu (weighted)",
        &["layer", "weight", "conv MB", "conv ms", "MEC MB", "MEC ms"],
        &rows,
    );
    println!(
        "\npaper: MEM ratio 3.2 (203.6/64.6 MB), RUNTIME ratio 1.2 (1701.6/1391.6 ms)\n\
         ours : MEM ratio {:.2} ({:.1}/{:.1} MB), RUNTIME ratio {:.2} ({:.0}/{:.0} ms)",
        tot[0] / tot[2],
        tot[0],
        tot[2],
        tot[1] / tot[3],
        tot[1],
        tot[3]
    );
}
