//! Figure 4(e): memory-overhead on Server-GPU across cv1–cv12 for
//! Conv.gpu, Wino.gpu (3×3 only), FFT.gpu, and MEC.gpu.
//!
//! GPU substitution (DESIGN.md §3/§6): memory-overhead is an allocator
//! fact — the lowered matrix / transform buffers / padded spectra have
//! the same sizes regardless of device — so these columns are *exact*
//! reproductions. FFT uses the paper-faithful model (every kernel padded
//! to input size, all spectra live).
//!
//! Paper's claims: MEC least on all 12 layers; FFT substantially largest.

use mec::bench::harness::print_table;
use mec::bench::workload::suite;
use mec::conv::{AlgoKind, Convolution};

fn main() {
    let batch = 32; // paper's server mini-batch
    let mut rows = Vec::new();
    let mut mec_least = true;
    let mut fft_max = true;
    for w in suite() {
        let shape = w.shape(batch, 1);
        let conv_b = AlgoKind::Im2col.build().workspace_bytes(&shape);
        let mec_b = AlgoKind::Mec.build().workspace_bytes(&shape);
        let fft_b = AlgoKind::Fft.build().workspace_bytes(&shape);
        let wino = AlgoKind::Winograd.build();
        let wino_b = wino.supports(&shape).then(|| wino.workspace_bytes(&shape));
        mec_least &= mec_b <= conv_b && mec_b <= fft_b && wino_b.map_or(true, |b| mec_b <= b);
        // The paper's FFT blow-up claim is about kernels much smaller
        // than the input (§2.2: "memory-overhead becomes really high
        // when kernels are relatively smaller (e.g., 3x3)"); on the
        // 11x11/s=4 layers im2col's own lowered matrix is comparable.
        if w.kh == 3 {
            fft_max &= fft_b >= conv_b;
        }
        rows.push(vec![
            w.name.to_string(),
            format!("{:.1}", conv_b as f64 / 1e6),
            wino_b.map_or("-".into(), |b| format!("{:.1}", b as f64 / 1e6)),
            format!("{:.1}", fft_b as f64 / 1e6),
            format!("{:.1}", mec_b as f64 / 1e6),
            format!("{:.1}x", conv_b as f64 / mec_b as f64),
            format!("{:.0}x", fft_b as f64 / mec_b as f64),
        ]);
    }
    print_table(
        "Fig 4e — memory-overhead (MB), Server-GPU(sim), batch 32",
        &["layer", "Conv.gpu", "Wino.gpu", "FFT.gpu", "MEC.gpu", "conv/mec", "fft/mec"],
        &rows,
    );
    println!(
        "\npaper shape holds: MEC least on all layers: {} | FFT largest on every 3x3 layer: {}",
        if mec_least { "YES ✓" } else { "NO ✗" },
        if fft_max { "YES ✓" } else { "NO ✗" }
    );
}
