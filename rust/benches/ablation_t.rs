//! Ablation: the dispatch threshold `T` (Algorithm 2 line 8).
//!
//! The paper tuned T ≈ 100 for GPUs. This sweep measures the auto
//! dispatcher at several T values on layers whose o_w straddles the
//! threshold, re-deriving the right T for this host.

use mec::bench::bench_conv;
use mec::bench::harness::{bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::mec::{Mec, Solution};
use mec::conv::{AlgoKind, ConvContext};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale().max(2);
    let opts = BenchOpts::default();
    let mut rng = Rng::new(8);
    let t_values = [1usize, 8, 25, 50, 100, 256];
    let mut rows = Vec::new();
    // Layers with small and large o_w to straddle the threshold.
    for name in ["cv5", "cv6", "cv9", "cv7", "cv12"] {
        let w = suite().into_iter().find(|w| w.name == name).unwrap();
        let shape = w.shape(4, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut cells = vec![format!("{name} (ow={})", shape.ow())];
        for &t in &t_values {
            let ctx = ConvContext::mobile().with_mec_t(t);
            let algo = AlgoKind::Mec.build();
            let bname = format!("{name}-T{t}");
            let r = bench_conv(&bname, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            let sol = match Mec::auto().resolve(&ctx, &shape) {
                Solution::A => "A",
                Solution::B => "B",
                Solution::Auto => "?",
            };
            cells.push(format!("{:.1}{}", r.median_ms(), sol));
        }
        rows.push(cells);
    }
    let header: Vec<String> = std::iter::once("layer".to_string())
        .chain(t_values.iter().map(|t| format!("T={t}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(
        "Ablation — MEC auto dispatch vs threshold T (ms, suffix = solution chosen)",
        &header_refs,
        &rows,
    );
    println!("\npaper found T≈100 good for GPUs; the crossover here tells this host's T.");
}
