//! Ablation: MEC Solution A vs Solution B vs the Algorithm-2 line-8
//! auto dispatch, across cv1–cv12 and batch sizes 1/8 — the design
//! choice §3.3 of the paper discusses (format handling + gemm-size
//! trade-off).

use mec::bench::bench_conv;
use mec::bench::harness::{bench_mode, bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::mec::{Mec, Solution};
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale().max(2);
    let ctx = ConvContext::mobile();
    let opts = BenchOpts::default();
    let mut rng = Rng::new(7);
    println!("timing mode: {}", bench_mode().label());
    for batch in [1usize, 8] {
        let mut rows = Vec::new();
        for w in suite() {
            let shape = w.shape(batch, scale);
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let mut out = Tensor::zeros(shape.output());
            let mut cells = vec![w.name.to_string()];
            for kind in [AlgoKind::MecSolutionA, AlgoKind::MecSolutionB, AlgoKind::Mec] {
                let algo = kind.build();
                let name = format!("b{batch}-{}-{}", w.name, algo.name());
                let r =
                    bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
                cells.push(format!("{:.1}", r.median_ms()));
            }
            let resolved = Mec::auto().resolve(&ctx, &shape);
            cells.push(
                match resolved {
                    Solution::A => "A",
                    Solution::B => "B",
                    Solution::Auto => "?",
                }
                .to_string(),
            );
            rows.push(cells);
        }
        print_table(
            &format!("Ablation — MEC Solution A vs B vs auto (ms), batch={batch}"),
            &["layer", "A", "B", "auto", "auto chose"],
            &rows,
        );
    }
    println!("\npaper §3.3: A amortizes gemm-call overhead into o_h big calls but pays a\nrepack; B has i_n·o_h small calls in native layout. T dispatch should track the winner.");
}
