//! Figure 4(b): memory-overhead on Mobile (batch 1) across cv1–cv12 for
//! Conv.cpu (im2col), Wino.cpu (cv6–cv12 only), and MEC.cpu.
//!
//! Memory numbers are allocator facts and therefore *exact* at paper
//! scale regardless of host speed — this bench runs at full scale and
//! also verifies measured peaks equal the analytic formulas.
//!
//! Paper's claims: MEC ~3.2× less than Conv.cpu on average (up to 3.4×),
//! and ~5.9× less than Wino.cpu on cv6–cv12.
//!
//! Beyond the paper's systems the table carries the menu's related-work
//! memory points: Indirect (lane-strip gather, `≤ GATHER_LANES` row
//! blocks of Eq. 2) and kn2row/SMM (exactly zero workspace — printed
//! once in the legend, not per row).

use mec::bench::harness::print_table;
use mec::bench::workload::suite;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::{measure_peak, Workspace};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let ctx = ConvContext::mobile();
    let mut rng = Rng::new(42);
    let mut rows = Vec::new();
    let mut conv_sum = 0.0;
    let mut wino_sum = 0.0;
    let mut wino_n = 0.0;
    for w in suite() {
        let shape = w.shape(1, 1);
        let conv_b = AlgoKind::Im2col.build().workspace_bytes(&shape);
        let mec_b = AlgoKind::Mec.build().workspace_bytes(&shape);
        let ind_b = AlgoKind::Indirect.build().workspace_bytes(&shape);
        let wino = AlgoKind::WinogradChunked.build();
        let wino_b = wino.supports(&shape).then(|| wino.workspace_bytes(&shape));

        // Verify measured == analytic on the layers cheap enough to run.
        let verified = if shape.input.len() < 2_000_000 {
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let mut out = Tensor::zeros(shape.output());
            let algo = AlgoKind::Mec.build();
            let ((), peak) = measure_peak(|| {
                let mut ws = Workspace::new();
                algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
            });
            if peak == mec_b { "✓" } else { "MISMATCH" }
        } else {
            "-"
        };

        conv_sum += conv_b as f64 / mec_b as f64;
        if let Some(wb) = wino_b {
            wino_sum += wb as f64 / mec_b as f64;
            wino_n += 1.0;
        }
        rows.push(vec![
            w.name.to_string(),
            format!("{:.2}", conv_b as f64 / 1e6),
            wino_b.map_or("-".into(), |b| format!("{:.2}", b as f64 / 1e6)),
            format!("{:.2}", mec_b as f64 / 1e6),
            format!("{:.2}", ind_b as f64 / 1e6),
            format!("{:.2}x", conv_b as f64 / mec_b as f64),
            verified.to_string(),
        ]);
    }
    print_table(
        "Fig 4b — memory-overhead (MB), Mobile, batch 1",
        &["layer", "Conv.cpu", "Wino.cpu", "MEC.cpu", "Indirect", "conv/mec", "measured==analytic"],
        &rows,
    );
    println!("\nkn2row / SMM-Conv: 0.00 MB on every layer (zero-workspace tier)");
    println!(
        "\naverages: Conv.cpu/MEC {:.2}x (paper: 3.2x, max 3.4x) | Wino.cpu/MEC {:.2}x on 3x3 layers (paper: 5.9x)",
        conv_sum / suite().len() as f64,
        wino_sum / wino_n
    );
}
