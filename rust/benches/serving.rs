//! Serving bench: the throughput / tail-latency / shed trade-off of the
//! SLO-aware serving layer, recorded as the `BENCH_serving.json`
//! trajectory.
//!
//! Two sweeps over one engine (paper layer cv6 behind the coordinator):
//!
//! * **closed loop** — N clients in submit-wait loops. Offered load
//!   self-regulates to capacity, so this measures how throughput climbs
//!   with concurrency (the adaptive batcher coalescing singles into the
//!   pinned shapes) and where p99 crosses the SLO.
//! * **open loop** — fixed-rate submission at fractions of the measured
//!   closed-loop capacity. Past saturation the honest failure mode
//!   appears: shed rate and tail latency blow up instead of throughput
//!   politely flattening (no coordinated omission — percentiles come
//!   from server-side histograms).
//!
//! Headline figure: best closed-loop throughput whose p99 still meets
//! the SLO ("throughput at fixed p99").
//!
//! Run: `cargo bench --bench serving`
//! (env: MEC_BENCH_FAST = smoke sweep, MEC_BENCH_SCALE shrinks channels,
//!  MEC_THREADS pins the engine pool width)

use mec::bench::harness::{bench_scale, bench_threads, print_table, threads_label};
use mec::bench::workload;
use mec::coordinator::{Server, ServerConfig};
use mec::engine::Engine;
use mec::serving::loadgen::{self, LoadConfig, LoadMode, LoadReport};
use std::sync::Arc;
use std::time::Duration;

const SLO_MS: f64 = 50.0;
const PINNED: &[usize] = &[1, 2, 4, 8];

fn run_point(engine: &Arc<Engine>, workers: usize, cfg: &LoadConfig) -> LoadReport {
    // Fresh server per point: shed/served counters and queue state
    // start clean, so each report stands alone (the engine — the
    // expensive part — is shared).
    let server = Server::start(
        Arc::clone(engine),
        ServerConfig {
            workers,
            queue_depth: 1024,
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .expect("server starts");
    let sample = {
        let (h, w, c) = engine.input_hwc();
        vec![0.2f32; h * w * c]
    };
    let report = loadgen::run(&server, &sample, cfg);
    server.shutdown();
    report
}

fn main() {
    let fast = std::env::var_os("MEC_BENCH_FAST").is_some();
    let scale = bench_scale();
    let threads = bench_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
        .max(2);
    let workers = 2;
    let w = workload::by_name("cv6").expect("cv6 in the paper suite");
    let engine = Arc::new(
        Engine::builder(w.model(scale, 0x6ec))
            .pin_batch_sizes(PINNED)
            .threads(threads)
            .build()
            .expect("cv6 engine builds"),
    );
    let slo = Some(Duration::from_secs_f64(SLO_MS / 1e3));
    let requests = if fast { 60 } else { 400 };
    println!(
        "Serving bench: cv6 (scale {scale}), {}, {workers} workers, \
         pinned {PINNED:?}, SLO {SLO_MS} ms, {requests} requests/point{}",
        threads_label(threads),
        if fast { " [smoke]" } else { "" }
    );

    // --- closed loop: capacity vs concurrency -----------------------
    let client_counts: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8, 16] };
    let mut reports: Vec<LoadReport> = client_counts
        .iter()
        .map(|&clients| {
            run_point(
                &engine,
                workers,
                &LoadConfig { mode: LoadMode::Closed { clients }, requests, slo },
            )
        })
        .collect();

    // --- open loop: fixed rates around the measured capacity --------
    // Rates are fractions of the best closed-loop throughput, so the
    // sweep brackets saturation on any machine at any scale.
    let capacity = reports
        .iter()
        .map(|r| r.throughput_rps)
        .fold(1.0f64, f64::max);
    let fractions: &[f64] = if fast { &[0.5, 1.25] } else { &[0.25, 0.5, 0.75, 1.0, 1.5] };
    for &frac in fractions {
        reports.push(run_point(
            &engine,
            workers,
            &LoadConfig { mode: LoadMode::Open { rps: capacity * frac }, requests, slo },
        ));
    }

    // --- degraded mode: the ladder's bottom rung under load ----------
    // A fresh engine (same config) forced down the degradation ladder
    // (`Engine::degrade` — the same re-plan a refused workspace
    // reservation triggers automatically): every conv layer on the
    // zero-workspace family. The point quantifies what graceful
    // degradation costs in throughput and tail latency at the same
    // offered load, so the trajectory records the fallback with real
    // numbers instead of a claim.
    let degraded_engine = Arc::new(
        Engine::builder(w.model(scale, 0x6ec))
            .pin_batch_sizes(PINNED)
            .threads(threads)
            .build()
            .expect("cv6 engine builds"),
    );
    let transitions = degraded_engine.degrade();
    println!(
        "\ndegraded point: {} conv layer(s) re-planned onto the zero-workspace family",
        transitions.len()
    );
    let degraded_clients = *client_counts.last().unwrap();
    let mut degraded = run_point(
        &degraded_engine,
        workers,
        &LoadConfig { mode: LoadMode::Closed { clients: degraded_clients }, requests, slo },
    );
    degraded.label = format!("degraded-{}", degraded.label);
    reports.push(degraded);

    // --- report -----------------------------------------------------
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.1}", r.offered_rps),
                format!("{:.1}", r.throughput_rps),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p90_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.1}%", 100.0 * r.shed_rate),
                format!("{:.3}", r.slo_attainment),
            ]
        })
        .collect();
    print_table(
        &format!("Serving sweep (SLO {SLO_MS} ms; latency = server-side histogram)"),
        &["load", "offered/s", "served/s", "p50 ms", "p90 ms", "p99 ms", "shed", "attain"],
        &rows,
    );
    match reports
        .iter()
        .filter(|r| r.label.starts_with("closed") && r.p99_ms <= SLO_MS)
        .max_by(|a, b| a.throughput_rps.total_cmp(&b.throughput_rps))
    {
        Some(best) => println!(
            "\nthroughput at p99 <= {SLO_MS} ms: {:.1} req/s ({})",
            best.throughput_rps, best.label
        ),
        None => println!("\nno closed-loop point met p99 <= {SLO_MS} ms on this machine"),
    }

    // Machine-readable trajectory point (same writer as the smoke
    // regeneration in tests/serving_slo.rs).
    let json = loadgen::render_json(SLO_MS, workers, PINNED, &reports);
    match std::fs::write("BENCH_serving.json", &json) {
        Ok(()) => println!("wrote BENCH_serving.json"),
        Err(e) => eprintln!("warning: could not write BENCH_serving.json: {e}"),
    }
}
