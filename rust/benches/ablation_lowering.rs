//! Ablation: lowering-only comparison — time and bytes for building the
//! im2col Toeplitz matrix vs MEC's compact L, across the suite. This
//! isolates the paper's Fig. 4f "85% faster lowering / fewer bytes
//! written" claim from the gemm phase entirely, and also isolates the
//! cache-locality argument (§4's Valgrind aside): the work per element
//! is identical copies, so the time ratio ≈ the byte ratio when the
//! memory system is the bottleneck.

use mec::bench::harness::{bench_fn, bench_scale, print_table, BenchOpts};
use mec::bench::workload::suite;
use mec::conv::im2col::Im2col;
use mec::conv::mec::Mec;
use mec::conv::ConvContext;
use mec::tensor::Tensor;
use mec::util::Rng;

fn main() {
    let scale = bench_scale();
    let ctx = ConvContext::mobile();
    let opts = BenchOpts::default();
    let mut rng = Rng::new(10);
    let mut rows = Vec::new();
    let mut byte_ratio_sum = 0.0;
    let mut time_ratio_sum = 0.0;
    for w in suite() {
        let shape = w.shape(1, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let mut l1 = vec![0.0f32; shape.im2col_lowered_elems()];
        let mut l2 = vec![0.0f32; shape.mec_lowered_elems()];
        let r1 = bench_fn(&format!("{}-i2c", w.name), &opts, || {
            Im2col::lower(&ctx, &shape, &input, &mut l1);
        });
        let r2 = bench_fn(&format!("{}-mec", w.name), &opts, || {
            Mec::lower(&ctx, &shape, &input, &mut l2);
        });
        let byte_ratio = l1.len() as f64 / l2.len() as f64;
        let time_ratio = r1.median_ns() / r2.median_ns();
        byte_ratio_sum += byte_ratio;
        time_ratio_sum += time_ratio;
        rows.push(vec![
            w.name.to_string(),
            format!("{:.2}", l1.len() as f64 * 4.0 / 1e6),
            format!("{:.2}", l2.len() as f64 * 4.0 / 1e6),
            format!("{byte_ratio:.2}x"),
            format!("{:.3}", r1.median_ms()),
            format!("{:.3}", r2.median_ms()),
            format!("{time_ratio:.2}x"),
        ]);
    }
    print_table(
        "Ablation — lowering only: im2col vs MEC",
        &["layer", "i2c MB", "mec MB", "bytes", "i2c ms", "mec ms", "speedup"],
        &rows,
    );
    let n = suite().len() as f64;
    println!(
        "\naverages: bytes-written ratio {:.2}x, lowering-time ratio {:.2}x\n\
         (paper Fig 4f: MEC lowering ~85% faster on GPU ⇔ ratio ~6.7x; on CPU the\n\
         copy loops are identical per-byte, so time ratio should track byte ratio)",
        byte_ratio_sum / n,
        time_ratio_sum / n
    );
}
