//! Algorithm-menu sweep: every [`AlgoKind::MENU`] entry on a contrasting
//! set of fixture geometries — the big-image/strided layer indirect is
//! built for (cv1), a mid 3×3 layer (cv6), and the pointwise anchors
//! (pw1/pw2) where kn2row's decomposition degenerates to a single
//! unshifted GEMM.
//!
//! Prints per (layer, algorithm): median execute time, the analytic
//! workspace, and what the cost-model planner would have picked for the
//! layer under an unlimited budget — eyeballable cost-model honesty (the
//! `algo_differential` suite asserts the 1.5× version of the same claim).
//!
//! Honors `MEC_BENCH_SCALE`, `MEC_BENCH_FAST`, `MEC_BENCH_MODE`,
//! `MEC_THREADS` like the fig4 benches.

use mec::bench::bench_conv;
use mec::bench::harness::{bench_mode, bench_scale, bench_threads, kernel_label, print_table};
use mec::bench::workload::by_name;
use mec::bench::BenchOpts;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::Budget;
use mec::planner::Planner;
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale();
    let mut ctx = ConvContext::mobile();
    if let Some(t) = bench_threads() {
        ctx = ctx.with_threads(t);
    }
    let opts = BenchOpts::default();
    let planner = Planner::new();
    let mut rng = Rng::new(0xa190);
    println!(
        "Algorithm menu sweep: {} algorithms, scale={scale}, mode={}, kernel: {}",
        AlgoKind::MENU.len(),
        bench_mode().label(),
        kernel_label()
    );
    let mut rows = Vec::new();
    for name in ["cv1", "cv6", "pw1", "pw2"] {
        let w = by_name(name).expect("fixture workload");
        let shape = w.shape(1, scale);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let planned = planner.plan(&shape, &Budget::unlimited(), &ctx).algo;
        for kind in AlgoKind::MENU {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            let label = format!("{name}-{kind}");
            let r = bench_conv(&label, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            rows.push(vec![
                name.to_string(),
                kind.to_string(),
                format!("{:.2}", r.median_ms()),
                format!("{:.2}", algo.workspace_bytes(&shape) as f64 / 1e6),
                if kind == planned {
                    "◀ planned".into()
                } else {
                    String::new()
                },
            ]);
        }
    }
    print_table(
        "Menu — median execute (ms) and workspace (MB) per algorithm",
        &["layer", "algo", "ms", "ws MB", "planner"],
        &rows,
    );
}
