//! Figure 4(a): cv1 (227×227×3, 11×11×96 kernel) with stride swept
//! 1..10 on Server-CPU — memory-overhead and runtime improvement factors
//! of MEC over im2col-based convolution.
//!
//! Paper's claim: both factors grow with the k/s ratio, per Eq. (4).
//! Run: `cargo bench --bench fig4a` (env: MEC_BENCH_FAST, MEC_BENCH_SCALE)

use mec::bench::bench_conv;
use mec::bench::harness::{
    bench_mode, bench_precision, bench_scale, kernel_label, print_table, threads_label, BenchOpts,
};
use mec::bench::workload::by_name;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
use mec::util::Rng;

fn main() {
    let scale = bench_scale();
    let base = by_name("cv1").unwrap();
    let ctx = ConvContext::server().with_precision(bench_precision());
    let opts = BenchOpts::default();
    let mut rng = Rng::new(41);
    let mut rows = Vec::new();
    println!(
        "Figure 4(a) reproduction: cv1, k=11x11 fixed, stride 1..10, {}, scale={scale}",
        threads_label(ctx.threads())
    );
    println!("timing mode: {}", bench_mode().label());
    println!(
        "precision: {} (set MEC_BENCH_PRECISION=q16 for the paper's fixed-point grid)",
        ctx.precision
    );
    println!("kernel: {}", kernel_label());
    for s in 1..=10usize {
        let ic = (base.ic / scale).max(1);
        let kc = (base.kc / scale).max(1);
        let shape = ConvShape::new(
            Nhwc::new(1, base.ih, base.iw, ic),
            KernelShape::new(base.kh, base.kw, ic, kc),
            s,
            s,
        );
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());

        let mem_i2c = AlgoKind::Im2col.build().workspace_bytes(&shape);
        let mem_mec = AlgoKind::Mec.build().workspace_bytes(&shape);

        let mut times = Vec::new();
        for kind in [AlgoKind::Im2col, AlgoKind::Mec] {
            let algo = kind.build();
            let name = format!("s{s}-{}", algo.name());
            let r = bench_conv(&name, &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
            times.push(r.median_ns());
        }
        rows.push(vec![
            s.to_string(),
            format!("{:.2}", base.kh as f64 / s as f64),
            format!("{:.2}", mem_i2c as f64 / mem_mec as f64),
            format!("{:.2}", times[0] / times[1]),
            format!("{:.1}", times[0] / 1e6),
            format!("{:.1}", times[1] / 1e6),
        ]);
    }
    print_table(
        "Fig 4a — MEC improvement factor over im2col vs stride (cv1)",
        &["s", "k/s", "mem factor", "time factor", "im2col ms", "mec ms"],
        &rows,
    );
    println!(
        "\npaper shape: both factors shrink toward 1 as s grows (less overlap);\n\
         mem factor is exact (Eq. 2 / Eq. 3); time factor is host-specific."
    );
}
