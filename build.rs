//! Build probe: enable the AVX-512 microkernel module only on toolchains
//! where the `_mm512_*` intrinsics are stable (Rust 1.89+).
//!
//! The repo pins no toolchain, so `gemm::micro::avx512` is compiled
//! behind a `mec_avx512` cfg that this script emits after asking the
//! active `rustc` for its version. On older compilers the module simply
//! does not exist and `KernelBackend::Avx512.available()` reports false;
//! dispatch falls back to AVX2/scalar. Any probe failure (missing rustc,
//! unparseable version) conservatively disables the module.

use std::env;
use std::process::Command;

fn rustc_minor() -> Option<(u32, u32)> {
    let rustc = env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (hash date)" — take the second whitespace field.
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-', '+']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
    // Declare the cfg so `unexpected_cfgs` (cargo >= 1.80) stays quiet on
    // builds where it is not set.
    println!("cargo:rustc-check-cfg=cfg(mec_avx512)");
    // The loom leg (`RUSTFLAGS="--cfg loom" cargo test --lib -- loom`)
    // swaps the threadpool's sync primitives for the in-tree model
    // checker; declare the cfg so normal builds don't warn about it.
    println!("cargo:rustc-check-cfg=cfg(loom)");
    if let Some((major, minor)) = rustc_minor() {
        if major > 1 || (major == 1 && minor >= 89) {
            println!("cargo:rustc-cfg=mec_avx512");
        }
    }
}
