"""Layer-2 JAX model: a small CNN whose convolutions run through the
Layer-1 MEC Pallas kernels.

Architecture (28×28×1 in, 3 classes out — the synthetic shapes task):

    conv 3×3×1×8  SAME → relu → maxpool 2
    conv 3×3×8×16 SAME → relu → maxpool 2
    flatten → dense 784→3

``use_pallas`` switches conv between the Pallas MEC kernel (the artifact
that gets AOT-lowered and served) and the pure-jnp reference (used for
the training loop, where we want fast ``jax.grad``). Both paths are
numerically identical — asserted in ``python/tests/test_model.py``.
"""

import jax
import jax.numpy as jnp

from .kernels import mec, ref

# (name, kh, kw, ic, kc, stride, pad)
CONV_SPECS = [
    ("conv1", 3, 3, 1, 8, 1, 1),
    ("conv2", 3, 3, 8, 16, 1, 1),
]
INPUT_HWC = (28, 28, 1)
NUM_CLASSES = 3
DENSE_IN = 7 * 7 * 16  # after two stride-2 pools: 28 -> 14 -> 7


def init_params(key):
    """He-style init, deterministic in ``key``."""
    params = {}
    for name, kh, kw, ic, kc, _s, _p in CONV_SPECS:
        key, k1 = jax.random.split(key)
        fan_in = kh * kw * ic
        params[name] = {
            "w": jax.random.normal(k1, (kh, kw, ic, kc), jnp.float32)
            * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((kc,), jnp.float32),
        }
    key, k1 = jax.random.split(key)
    params["dense"] = {
        "w": jax.random.normal(k1, (DENSE_IN, NUM_CLASSES), jnp.float32)
        * jnp.sqrt(2.0 / DENSE_IN),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params


def conv_layer(x, w, b, stride, pad, use_pallas):
    """SAME-padded conv through MEC (pallas) or the reference."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    if use_pallas:
        y = mec.mec_conv(x, w, (stride, stride))
    else:
        y = ref.conv2d_ref(x, w, (stride, stride))
    return y + b


def max_pool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


def forward(params, x, use_pallas=False):
    """Logits for a batch ``(n, 28, 28, 1) -> (n, 3)``."""
    for name, _kh, _kw, _ic, _kc, s, p in CONV_SPECS:
        x = conv_layer(x, params[name]["w"], params[name]["b"], s, p, use_pallas)
        x = jax.nn.relu(x)
        x = max_pool(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["dense"]["w"] + params["dense"]["b"]


def predict_proba(params, x, use_pallas=False):
    return jax.nn.softmax(forward(params, x, use_pallas), axis=-1)


def loss_fn(params, x, y):
    """Mean cross-entropy (training uses the reference conv path)."""
    logits = forward(params, x, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(params, x, y, use_pallas=False):
    preds = jnp.argmax(forward(params, x, use_pallas), axis=-1)
    return jnp.mean((preds == y).astype(jnp.float32))
