"""Synthetic "shapes" dataset for the end-to-end training/serving demo.

Three 28×28 grayscale classes with additive noise and random jitter:
  0 — filled square
  1 — cross (plus sign)
  2 — diagonal stripes

Deterministic in the seed; split into train/eval by the generator.
This stands in for the proprietary/real datasets the paper's DNNs were
trained on (substitution documented in DESIGN.md §6): the serving demo
needs *a* real learning task to prove the full stack trains and serves,
not ImageNet itself.
"""

import numpy as np

H = W = 28


def _square(rng):
    img = np.zeros((H, W), np.float32)
    size = rng.integers(8, 16)
    y = rng.integers(2, H - size - 2)
    x = rng.integers(2, W - size - 2)
    img[y : y + size, x : x + size] = 1.0
    return img


def _cross(rng):
    img = np.zeros((H, W), np.float32)
    cy = rng.integers(10, H - 10)
    cx = rng.integers(10, W - 10)
    t = rng.integers(2, 4)
    arm = rng.integers(6, 10)
    img[cy - t : cy + t, cx - arm : cx + arm] = 1.0
    img[cy - arm : cy + arm, cx - t : cx + t] = 1.0
    return img


def _stripes(rng):
    img = np.zeros((H, W), np.float32)
    period = rng.integers(4, 7)
    phase = rng.integers(0, period)
    yy, xx = np.mgrid[0:H, 0:W]
    img[((yy + xx + phase) % period) < period // 2] = 1.0
    return img


_MAKERS = [_square, _cross, _stripes]


def make_dataset(n, seed=0, noise=0.25):
    """Returns ``(images (n,28,28,1) float32 in [0,1]-ish, labels (n,) int32)``."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, H, W, 1), np.float32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        cls = int(rng.integers(0, 3))
        img = _MAKERS[cls](rng)
        img = img + rng.normal(0.0, noise, img.shape).astype(np.float32)
        xs[i, :, :, 0] = img
        ys[i] = cls
    return xs, ys


def save_eval_bin(path, xs, ys):
    """Binary eval set for the rust serve example:

    ``u32 count, u32 h, u32 w, u32 c``, then per sample
    ``f32[h·w·c] pixels, u32 label`` (little-endian).
    """
    n, h, w, c = xs.shape
    with open(path, "wb") as f:
        for v in (n, h, w, c):
            f.write(np.uint32(v).tobytes())
        for i in range(n):
            f.write(xs[i].astype("<f4").tobytes())
            f.write(np.uint32(ys[i]).tobytes())
