"""Build-time trainer: fit the L2 CNN on the synthetic shapes dataset,
log the loss curve, and export everything the rust side needs:

* ``model.mecw``      — weights in the rust loader's format
* ``eval.bin``        — held-out eval set for the serve example
* ``params.npz``      — raw params for ``aot.py`` (keeps the AOT module
                        self-contained)
* ``loss_curve.txt``  — step,loss pairs (recorded into EXPERIMENTS.md)

Runs once under ``make artifacts``; never on the serve path.
"""

import argparse
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def sgd_momentum(params, grads, vel, lr, mu=0.9):
    new_vel = jax.tree_util.tree_map(lambda v, g: mu * v + g, vel, grads)
    new_params = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, new_vel)
    return new_params, new_vel


def train(steps=400, batch=64, lr=0.01, seed=0, log_every=20):
    """Returns (params, loss_curve [(step, loss)], eval_acc, eval set)."""
    xs, ys = data.make_dataset(4096, seed=seed)
    ex, ey = data.make_dataset(512, seed=seed + 1)
    params = model.init_params(jax.random.PRNGKey(seed))
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    loss_grad = jax.jit(jax.value_and_grad(model.loss_fn))
    rng = np.random.default_rng(seed + 2)
    curve = []
    for step in range(steps):
        idx = rng.integers(0, len(xs), batch)
        loss, grads = loss_grad(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        params, vel = sgd_momentum(params, vel, grads, lr)
        if step % log_every == 0 or step == steps - 1:
            curve.append((step, float(loss)))
    acc = float(model.accuracy(params, jnp.asarray(ex), jnp.asarray(ey)))
    return params, curve, acc, (ex, ey)


# ---------------------------------------------------------------- .mecw --

_TAG_CONV, _TAG_RELU, _TAG_MAXPOOL, _TAG_FLATTEN, _TAG_DENSE, _TAG_SOFTMAX = range(6)


def _w32(f, v):
    f.write(struct.pack("<I", v))


def _wf32s(f, arr):
    f.write(np.asarray(arr, dtype="<f4").tobytes())


def save_mecw(path, params, name="shapes-cnn"):
    """Mirror of rust ``model::loader`` (see its format doc)."""
    h, w, c = model.INPUT_HWC
    with open(path, "wb") as f:
        f.write(b"MECW0001")
        nb = name.encode()
        _w32(f, len(nb))
        f.write(nb)
        for v in (h, w, c):
            _w32(f, v)
        # conv1,relu,pool, conv2,relu,pool, flatten, dense, softmax
        layers = 3 * len(model.CONV_SPECS) + 3
        _w32(f, layers)
        for cname, kh, kw, ic, kc, s, p in model.CONV_SPECS:
            _w32(f, _TAG_CONV)
            for v in (kh, kw, ic, kc, s, s, p, p):
                _w32(f, v)
            _wf32s(f, params[cname]["w"])  # (kh,kw,ic,kc) row-major = loader layout
            _wf32s(f, params[cname]["b"])
            _w32(f, _TAG_RELU)
            _w32(f, _TAG_MAXPOOL)
            _w32(f, 2)
            _w32(f, 2)
        _w32(f, _TAG_FLATTEN)
        _w32(f, _TAG_DENSE)
        _w32(f, model.DENSE_IN)
        _w32(f, model.NUM_CLASSES)
        _wf32s(f, params["dense"]["w"])
        _wf32s(f, params["dense"]["b"])
        _w32(f, _TAG_SOFTMAX)


def save_params_npz(path, params):
    flat = {}
    for k, v in params.items():
        for kk, vv in v.items():
            flat[f"{k}/{kk}"] = np.asarray(vv)
    np.savez(path, **flat)


def load_params_npz(path):
    flat = np.load(path)
    params = {}
    for key in flat.files:
        k, kk = key.split("/")
        params.setdefault(k, {})[kk] = jnp.asarray(flat[key])
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("MEC_TRAIN_STEPS", 400)))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    params, curve, acc, (ex, ey) = train(steps=args.steps)
    dt = time.time() - t0
    print(f"trained {args.steps} steps in {dt:.1f}s; eval accuracy {acc:.3f}")
    assert acc > 0.85, f"training failed to converge (acc={acc})"

    save_mecw(os.path.join(args.out, "model.mecw"), params)
    save_params_npz(os.path.join(args.out, "params.npz"), params)
    data.save_eval_bin(os.path.join(args.out, "eval.bin"), ex[:256], ey[:256])
    with open(os.path.join(args.out, "loss_curve.txt"), "w") as f:
        f.write("# step loss (shapes-cnn, synthetic 3-class, SGD+momentum)\n")
        for step, loss in curve:
            f.write(f"{step} {loss:.5f}\n")
        f.write(f"# eval_accuracy {acc:.4f}\n")
    print(f"wrote model.mecw / params.npz / eval.bin / loss_curve.txt to {args.out}")


if __name__ == "__main__":
    main()
