"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, never ``.serialize()``: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (+ manifest.txt index, parsed by rust ``runtime::artifacts``):

* ``model_fwd.hlo.txt``  — the trained CNN forward (Pallas MEC convs),
  batch ``SERVE_BATCH``, probabilities out. Served by the PJRT executor
  and cross-checked against the native engine.
* ``conv_<layer>.hlo.txt`` — standalone MEC convolution for a couple of
  paper layers (channel-scaled), inputs (x, k): the kernel-level bridge
  the runtime integration tests exercise.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import mec
from .trainer import load_params_npz

SERVE_BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_order():
    """Weight inputs in a fixed order shared with the rust executor:
    per conv layer (w, b), then dense (w, b).

    Weights are runtime *parameters*, not closure constants: the pinned
    xla_extension 0.5.1 HLO-text parser mis-parses the multi-dimensional
    f32 constant literals jax ≥0.8 emits (silently wrong numerics —
    found by the rust cross-check test, see EXPERIMENTS.md §Findings).
    Parameters round-trip exactly, and match how serving systems feed
    weights anyway.
    """
    order = []
    for name, kh, kw, ic, kc, _s, _p in model.CONV_SPECS:
        order.append((name, "w", (kh, kw, ic, kc)))
        order.append((name, "b", (kc,)))
    order.append(("dense", "w", (model.DENSE_IN, model.NUM_CLASSES)))
    order.append(("dense", "b", (model.NUM_CLASSES,)))
    return order


def lower_model_fwd(params, batch=SERVE_BATCH):
    """Probabilities with the Pallas MEC conv path baked in."""
    h, w, c = model.INPUT_HWC
    order = weight_order()

    def fwd(x, *weights):
        p = {}
        for (lname, key, _shape), wv in zip(order, weights):
            p.setdefault(lname, {})[key] = wv
        return model.predict_proba(p, x, use_pallas=True)

    specs = [jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)] + [
        jax.ShapeDtypeStruct(shape, jnp.float32) for (_n, _k, shape) in order
    ]
    lowered = jax.jit(fwd).lower(*specs)
    in_shapes = [(batch, h, w, c)] + [shape for (_n, _k, shape) in order]
    del params  # weights flow in at run time
    return to_hlo_text(lowered), in_shapes, (batch, model.NUM_CLASSES)


# Standalone conv artifacts: (name, ih, iw, ic, kh, kw, kc, stride).
# cv6/cv12 geometries channel-scaled /8 to keep interpret-mode HLO compact.
CONV_ARTIFACTS = [
    ("conv_cv6s", 12, 12, 32, 3, 3, 64, 1),
    ("conv_cv12s", 7, 7, 64, 3, 3, 64, 1),
    ("conv_cv1s", 32, 32, 3, 11, 11, 12, 4),
]


def lower_conv(ih, iw, ic, kh, kw, kc, stride, batch=1):
    def conv(x, k):
        return mec.mec_conv(x, k, (stride, stride))

    xs = jax.ShapeDtypeStruct((batch, ih, iw, ic), jnp.float32)
    ks = jax.ShapeDtypeStruct((kh, kw, ic, kc), jnp.float32)
    lowered = jax.jit(conv).lower(xs, ks)
    oh = (ih - kh) // stride + 1
    ow = (iw - kw) // stride + 1
    return (
        to_hlo_text(lowered),
        [(batch, ih, iw, ic), (kh, kw, ic, kc)],
        (batch, oh, ow, kc),
    )


def fmt_shape(s):
    return ",".join(str(d) for d in s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = ["# MEC AOT artifacts (HLO text; see python/compile/aot.py)"]

    params = load_params_npz(os.path.join(args.out, "params.npz"))
    text, ishapes, oshape = lower_model_fwd(params)
    with open(os.path.join(args.out, "model_fwd.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append(
        f"name=model_fwd file=model_fwd.hlo.txt "
        f"inputs={';'.join(fmt_shape(s) for s in ishapes)} outputs={fmt_shape(oshape)}"
    )
    print(f"model_fwd: {len(text)} chars, in {ishapes[0]} (+{len(ishapes) - 1} weights) out {oshape}")

    for name, ih, iw, ic, kh, kw, kc, s in CONV_ARTIFACTS:
        text, ins, out = lower_conv(ih, iw, ic, kh, kw, kc, s)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(
            f"name={name} file={fname} "
            f"inputs={';'.join(fmt_shape(i) for i in ins)} outputs={fmt_shape(out)}"
        )
        print(f"{name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest) - 1} artifacts")


if __name__ == "__main__":
    main()
