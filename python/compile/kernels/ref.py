"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the *reference* semantics: NHWC convolution via
``jax.lax.conv_general_dilated`` and a plain-jnp MEC lowering.
The Pallas kernels in ``mec.py`` are asserted against these in
``python/tests`` (the core L1 correctness signal).
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, k, stride=(1, 1)):
    """VALID NHWC convolution (cross-correlation, CNN convention).

    Args:
      x: input, ``(n, ih, iw, ic)``.
      k: kernel, ``(kh, kw, ic, kc)``.
      stride: ``(sh, sw)``.

    Returns:
      ``(n, oh, ow, kc)`` with ``o = (i - k) / s + 1`` (paper Eq. 1).
    """
    return jax.lax.conv_general_dilated(
        x,
        k,
        window_strides=stride,
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def mec_lower_ref(x, kw, sw=1):
    """Reference MEC lowering (paper Algorithm 2 lines 4-6).

    Produces L of shape ``(n, ow, ih, kw, ic)``: L[n, w] is the vertical
    strip I[n, :, sw*w : sw*w + kw, :].
    """
    n, ih, iw, ic = x.shape
    ow = (iw - kw) // sw + 1
    cols = jnp.stack(
        [jax.lax.dynamic_slice(x, (0, 0, sw * w, 0), (n, ih, kw, ic)) for w in range(ow)],
        axis=1,
    )
    return cols  # (n, ow, ih, kw, ic)


def mec_conv_ref(x, k, stride=(1, 1)):
    """MEC evaluated with plain jnp ops (no Pallas): lower, then multiply
    the o_h overlapping partitions (paper §3.2 / Algorithm 2 Solution B).

    Numerically identical to ``conv2d_ref`` — used to test the algebra
    of the lowering independent of the Pallas implementation.
    """
    n, ih, iw, ic = x.shape
    kh, kw, _, kc = k.shape
    sh, sw = stride
    oh = (ih - kh) // sh + 1
    ow = (iw - kw) // sw + 1
    l = mec_lower_ref(x, kw, sw).reshape(n, ow, ih * kw * ic)
    kmat = k.reshape(kh * kw * ic, kc)
    rows = []
    for h in range(oh):
        # Partition h: columns [h·sh·kw·ic : h·sh·kw·ic + kh·kw·ic).
        a = jax.lax.dynamic_slice(l, (0, 0, h * sh * kw * ic), (n, ow, kh * kw * ic))
        rows.append(jnp.einsum("nwk,kc->nwc", a, kmat))
    return jnp.stack(rows, axis=1)  # (n, oh, ow, kc)
