"""Layer-1 baseline: im2col lowering as a Pallas kernel (paper Fig. 1b).

Used for the kernel-level memory comparison (Eq. 2 vs Eq. 3) and as the
Pallas-side baseline mirroring the rust engine's ``conv::im2col``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lower_kernel(x_ref, l_ref, *, sh, sw, kh, kw, ow):
    """One grid step: lowered row for output position (y, x) of sample n.

    Grid = (n, oh*ow); each program linearizes one receptive field — this
    is the per-output-position copy whose redundancy MEC eliminates.
    """
    t = pl.program_id(1)
    ic = x_ref.shape[3]
    y = t // ow
    x = t % ow
    l_ref[0, 0] = jax.lax.dynamic_slice(x_ref[0], (y * sh, x * sw, 0), (kh, kw, ic))


def im2col_lower(x, k_shape, stride=(1, 1), *, interpret=True):
    """Toeplitz lowering: ``(n, ih, iw, ic) -> (n, oh·ow, kh, kw, ic)``.

    Element count is Eq. (2) — compare ``mec.mec_lower``'s Eq. (3).
    """
    n, ih, iw, ic = x.shape
    kh, kw = k_shape[0], k_shape[1]
    sh, sw = stride
    oh = (ih - kh) // sh + 1
    ow = (iw - kw) // sw + 1
    return pl.pallas_call(
        functools.partial(_lower_kernel, sh=sh, sw=sw, kh=kh, kw=kw, ow=ow),
        grid=(n, oh * ow),
        in_specs=[pl.BlockSpec((1, ih, iw, ic), lambda i, j: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, kh, kw, ic), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh * ow, kh, kw, ic), x.dtype),
        interpret=interpret,
    )(x)


def im2col_conv(x, k, stride=(1, 1), *, interpret=True):
    """im2col convolution: lower + one big GEMM (paper Fig. 1b)."""
    n, ih, iw, ic = x.shape
    kh, kw, _, kc = k.shape
    sh, sw = stride
    oh = (ih - kh) // sh + 1
    ow = (iw - kw) // sw + 1
    l = im2col_lower(x, k.shape, stride, interpret=interpret)
    lmat = l.reshape(n * oh * ow, kh * kw * ic)
    kmat = k.reshape(kh * kw * ic, kc)
    return jnp.dot(lmat, kmat).reshape(n, oh, ow, kc)
