"""Layer-1 Pallas kernels: MEC convolution (paper Algorithm 2).

Two kernels compose into the full convolution, mirroring the paper:

* :func:`mec_lower` — Algorithm 2 lines 4-6: a grid over ``(n, w)``; each
  program copies one vertical strip ``I[n, :, s_w·w : s_w·w + k_w, :]``
  into row ``(n, w)`` of the compact lowered tensor L (Eq. 3).
* :func:`mec_multiply` — lines 21-25 (Solution B shape): a grid over
  ``(n, h)``; program ``(n, h)`` multiplies the *overlapping* slice
  ``L[n, :, h·s_h·k_w·i_c : … + k_h·k_w·i_c]`` by the kernel matrix on
  the MXU. The overlap is expressed by ``dynamic_slice`` into L held in
  VMEM — the Pallas restatement of the paper's BLAS ``ld`` trick.

HARDWARE ADAPTATION (DESIGN.md §3): the paper's GPU path batches these
GEMMs via ``cublasSgemmBatched``; on TPU the batch dimension *is* the
Pallas grid, and each step feeds an ``(o_w × k_h·k_w·i_c)`` tile through
the MXU. VMEM footprint per grid step = one sample's L row-block +
kernel matrix — see DESIGN.md §7 for per-layer numbers.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which both pytest and
the rust runtime execute. Real-TPU compilation is a compile-only target.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lower_kernel(x_ref, l_ref, *, sw, kw):
    """One grid step: copy strip w of sample n (grid = (n, ow))."""
    w = pl.program_id(1)
    ih, _, ic = x_ref.shape[1:]
    # L[n, w] = I[n, :, sw*w : sw*w+kw, :]  (Algorithm 2 line 5)
    l_ref[0, 0] = jax.lax.dynamic_slice(x_ref[0], (0, sw * w, 0), (ih, kw, ic))


def mec_lower(x, kw, sw=1, *, interpret=True):
    """Compact MEC lowering: ``(n, ih, iw, ic) -> (n, ow, ih, kw, ic)``."""
    n, ih, iw, ic = x.shape
    ow = (iw - kw) // sw + 1
    return pl.pallas_call(
        functools.partial(_lower_kernel, sw=sw, kw=kw),
        grid=(n, ow),
        in_specs=[pl.BlockSpec((1, ih, iw, ic), lambda i, j: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, ih, kw, ic), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ow, ih, kw, ic), x.dtype),
        interpret=interpret,
    )(x)


def _multiply_kernel(l_ref, k_ref, o_ref, *, sh, kw, ic, kh):
    """One grid step: output row h of sample n (grid = (n, oh))."""
    h = pl.program_id(1)
    ow = l_ref.shape[1]
    # Overlapping partition h of L (the ld trick, paper §3.2):
    a = jax.lax.dynamic_slice(
        l_ref[0], (0, h * sh * kw * ic), (ow, kh * kw * ic)
    )
    # (ow × kh·kw·ic) @ (kh·kw·ic × kc) on the MXU.
    o_ref[0, 0] = jnp.dot(a, k_ref[...], preferred_element_type=o_ref.dtype)


def mec_multiply(l, k, sh=1, *, interpret=True):
    """Recover the convolution from L: ``-> (n, oh, ow, kc)``.

    Args:
      l: lowered tensor ``(n, ow, ih, kw, ic)`` from :func:`mec_lower`.
      k: kernel ``(kh, kw, ic, kc)``.
      sh: vertical stride.
    """
    n, ow, ih, kw, ic = l.shape
    kh, kw2, ic2, kc = k.shape
    assert (kw2, ic2) == (kw, ic), f"kernel {k.shape} vs lowered {l.shape}"
    oh = (ih - kh) // sh + 1
    l2 = l.reshape(n, ow, ih * kw * ic)
    kmat = k.reshape(kh * kw * ic, kc)
    return pl.pallas_call(
        functools.partial(_multiply_kernel, sh=sh, kw=kw, ic=ic, kh=kh),
        grid=(n, oh),
        in_specs=[
            pl.BlockSpec((1, ow, ih * kw * ic), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((kh * kw * ic, kc), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ow, kc), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, kc), l.dtype),
        interpret=interpret,
    )(l2, kmat)


def mec_conv(x, k, stride=(1, 1), *, interpret=True):
    """Full MEC convolution (Algorithm 2): lower + multiply.

    Drop-in equal to :func:`..ref.conv2d_ref` — asserted by pytest.
    """
    sh, sw = stride
    l = mec_lower(x, k.shape[1], sw, interpret=interpret)
    return mec_multiply(l, k, sh, interpret=interpret)


def mec_lowered_elems(x_shape, k_shape, stride=(1, 1)):
    """Eq. (3): element count of L (memory-overhead accounting)."""
    n, ih, iw, ic = x_shape
    kh, kw, _, kc = k_shape
    _, sw = stride
    ow = (iw - kw) // sw + 1
    return n * ow * ih * kw * ic


def im2col_lowered_elems(x_shape, k_shape, stride=(1, 1)):
    """Eq. (2): element count of im2col's lowered matrix."""
    n, ih, iw, ic = x_shape
    kh, kw, _, kc = k_shape
    sh, sw = stride
    oh = (ih - kh) // sh + 1
    ow = (iw - kw) // sw + 1
    return n * oh * ow * kh * kw * ic
