"""AOT pipeline: HLO text generation, manifest format, mecw writer."""

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model, trainer


def test_conv_artifact_lowers_to_hlo_text():
    text, ins, out = aot.lower_conv(8, 8, 2, 3, 3, 4, 1)
    assert text.startswith("HloModule")
    # The pallas grid lowers to a while loop + dynamic slices in HLO.
    assert "dynamic-slice" in text or "while" in text
    assert ins[0] == (1, 8, 8, 2)
    assert out == (1, 6, 6, 4)


def test_model_fwd_lowers_with_pallas_path():
    params = model.init_params(jax.random.PRNGKey(0))
    text, ishapes, oshape = aot.lower_model_fwd(params, batch=2)
    assert text.startswith("HloModule")
    assert ishapes[0] == (2, 28, 28, 1)
    # Weights are parameters (the 0.5.1 constant-parsing workaround):
    # per conv (w, b) + dense (w, b).
    assert len(ishapes) == 1 + 2 * len(model.CONV_SPECS) + 2
    assert oshape == (2, model.NUM_CLASSES)
    # No multi-dim f32 weight constants may remain in the entry graph.
    assert text.count("parameter(") >= len(ishapes)


def test_weight_order_matches_conv_specs():
    order = aot.weight_order()
    assert order[0][:2] == ("conv1", "w")
    assert order[1][:2] == ("conv1", "b")
    assert order[-2][:2] == ("dense", "w")
    assert order[-1][:2] == ("dense", "b")


def test_manifest_shape_formatting():
    assert aot.fmt_shape((1, 2, 3)) == "1,2,3"


def test_mecw_writer_matches_rust_layout(tmp_path):
    """Byte-level spot check of the header the rust loader parses."""
    params = model.init_params(jax.random.PRNGKey(3))
    p = tmp_path / "m.mecw"
    trainer.save_mecw(p, params, name="abc")
    raw = p.read_bytes()
    assert raw[:8] == b"MECW0001"
    (name_len,) = struct.unpack_from("<I", raw, 8)
    assert name_len == 3
    assert raw[12:15] == b"abc"
    h, w, c, layers = struct.unpack_from("<IIII", raw, 15)
    assert (h, w, c) == model.INPUT_HWC
    assert layers == 3 * len(model.CONV_SPECS) + 3
    # First layer tag must be conv (0) with kh=kw=3.
    tag, kh, kw = struct.unpack_from("<III", raw, 31)
    assert (tag, kh, kw) == (0, 3, 3)


def test_params_npz_roundtrip(tmp_path):
    params = model.init_params(jax.random.PRNGKey(4))
    p = tmp_path / "p.npz"
    trainer.save_params_npz(p, params)
    loaded = trainer.load_params_npz(p)
    for lname, sub in params.items():
        for k, v in sub.items():
            np.testing.assert_allclose(
                np.asarray(loaded[lname][k]), np.asarray(v), rtol=1e-6
            )


def test_lowered_conv_numerics_roundtrip():
    """Execute the lowered-for-AOT function in-process and compare to the
    oracle — guards against lowering changing semantics."""
    from compile.kernels import mec, ref

    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 8, 2), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 2, 4), jnp.float32)
    jitted = jax.jit(lambda a, b: mec.mec_conv(a, b, (1, 1)))
    np.testing.assert_allclose(
        np.asarray(jitted(x, k)),
        np.asarray(ref.conv2d_ref(x, k, (1, 1))),
        rtol=2e-4,
        atol=1e-4,
    )
