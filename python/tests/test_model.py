"""L2 correctness: model shapes, pallas-vs-reference forward equality."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, model


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 28, 28, 1), jnp.float32)
    logits = model.forward(params, x)
    assert logits.shape == (4, 3)
    probs = model.predict_proba(params, x)
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), 1.0, rtol=1e-5)


def test_pallas_and_reference_paths_agree():
    """The artifact we serve (pallas path) must equal the training path."""
    params = model.init_params(jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 28, 28, 1), jnp.float32)
    ref_logits = model.forward(params, x, use_pallas=False)
    pallas_logits = model.forward(params, x, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(pallas_logits), np.asarray(ref_logits), rtol=1e-4, atol=1e-4
    )


def test_loss_decreases_on_tiny_run():
    from compile import trainer

    _, curve, _, _ = trainer.train(steps=60, batch=32, log_every=10)
    assert curve[0][1] > curve[-1][1], f"loss did not decrease: {curve}"


def test_dataset_is_deterministic_and_balancedish():
    x1, y1 = data.make_dataset(128, seed=5)
    x2, y2 = data.make_dataset(128, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (128, 28, 28, 1)
    counts = np.bincount(y1, minlength=3)
    assert (counts > 20).all(), counts


def test_eval_bin_format(tmp_path):
    xs, ys = data.make_dataset(10, seed=1)
    p = tmp_path / "eval.bin"
    data.save_eval_bin(p, xs, ys)
    raw = p.read_bytes()
    n, h, w, c = np.frombuffer(raw[:16], "<u4")
    assert (n, h, w, c) == (10, 28, 28, 1)
    rec = h * w * c * 4 + 4
    assert len(raw) == 16 + n * rec
    # First sample pixels + label round-trip.
    px = np.frombuffer(raw[16 : 16 + h * w * c * 4], "<f4").reshape(h, w, c)
    np.testing.assert_allclose(px, xs[0], rtol=1e-6)
    label = np.frombuffer(raw[16 + h * w * c * 4 : 16 + rec], "<u4")[0]
    assert label == ys[0]
