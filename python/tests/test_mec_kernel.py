"""L1 correctness: the Pallas MEC kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compile path: if these pass,
the HLO the rust runtime serves computes the paper's convolution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import im2col, mec, ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ------------------------------------------------------------ lowering --


def test_lower_matches_fig2_shape():
    # Paper Fig. 2: 7x7 input, kw=3, sw=1 -> L is (1, 5, 7, 3, 1): 5x21.
    x = jnp.arange(49, dtype=jnp.float32).reshape(1, 7, 7, 1)
    l = mec.mec_lower(x, kw=3, sw=1)
    assert l.shape == (1, 5, 7, 3, 1)
    # Partition A = I[0:7, 0:3] (paper's first shaded strip).
    np.testing.assert_array_equal(
        np.asarray(l[0, 0, :, :, 0]), np.asarray(x[0, :, 0:3, 0])
    )
    # Partition B = I[0:7, 1:4].
    np.testing.assert_array_equal(
        np.asarray(l[0, 1, :, :, 0]), np.asarray(x[0, :, 1:4, 0])
    )


def test_lower_matches_reference():
    x = rand(0, (2, 9, 11, 3))
    for kw, sw in [(3, 1), (3, 2), (5, 3), (1, 1)]:
        got = mec.mec_lower(x, kw=kw, sw=sw)
        want = ref.mec_lower_ref(x, kw=kw, sw=sw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_eq3_memory_accounting():
    # Fig. 2 numbers: MEC L = 105 elems vs im2col 225.
    assert mec.mec_lowered_elems((1, 7, 7, 1), (3, 3, 1, 1)) == 105
    assert mec.im2col_lowered_elems((1, 7, 7, 1), (3, 3, 1, 1)) == 225


# ---------------------------------------------------------- full conv --


@pytest.mark.parametrize(
    "n,ih,iw,ic,kh,kw,kc,sh,sw",
    [
        (1, 7, 7, 1, 3, 3, 1, 1, 1),      # paper Fig. 1/2 geometry
        (2, 9, 8, 3, 3, 2, 4, 2, 1),
        (1, 12, 10, 2, 5, 5, 3, 2, 2),
        (3, 6, 6, 4, 1, 1, 8, 1, 1),      # 1x1 conv
        (1, 11, 5, 2, 4, 3, 2, 3, 2),     # k < s in one dim
        (1, 12, 12, 8, 3, 3, 16, 1, 1),   # cv6-like (scaled)
    ],
)
def test_mec_conv_matches_lax(n, ih, iw, ic, kh, kw, kc, sh, sw):
    x = rand(n * 100 + ih, (n, ih, iw, ic))
    k = rand(kh * 10 + kw, (kh, kw, ic, kc))
    want = ref.conv2d_ref(x, k, (sh, sw))
    got = mec.mec_conv(x, k, (sh, sw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-4)


def test_im2col_conv_matches_lax():
    x = rand(5, (2, 8, 9, 3))
    k = rand(6, (3, 3, 3, 4))
    want = ref.conv2d_ref(x, k, (2, 1))
    got = im2col.im2col_conv(x, k, (2, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-4)


def test_mec_conv_ref_algebra():
    # The jnp restatement of Algorithm 2 (no pallas) is also exact.
    x = rand(7, (2, 10, 7, 2))
    k = rand(8, (3, 3, 2, 5))
    want = ref.conv2d_ref(x, k, (1, 2))
    got = ref.mec_conv_ref(x, k, (1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-4)


# -------------------------------------------------------- hypothesis --


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 2),
    ih=st.integers(4, 10),
    iw=st.integers(4, 10),
    ic=st.integers(1, 3),
    kh=st.integers(1, 3),
    kw=st.integers(1, 3),
    kc=st.integers(1, 4),
    sh=st.integers(1, 2),
    sw=st.integers(1, 2),
    seed=st.integers(0, 2**16),
)
def test_mec_conv_property(n, ih, iw, ic, kh, kw, kc, sh, sw, seed):
    """MEC == lax.conv for every geometry where the output is non-empty."""
    if ih < kh or iw < kw:
        return
    x = rand(seed, (n, ih, iw, ic))
    k = rand(seed + 1, (kh, kw, ic, kc))
    want = ref.conv2d_ref(x, k, (sh, sw))
    got = mec.mec_conv(x, k, (sh, sw))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    iw=st.integers(4, 12),
    kw=st.integers(1, 4),
    sw=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_lower_property(iw, kw, sw, seed):
    """Every lowered strip equals the corresponding input slice."""
    if iw < kw:
        return
    x = rand(seed, (1, 5, iw, 2))
    l = np.asarray(mec.mec_lower(x, kw=kw, sw=sw))
    xn = np.asarray(x)
    ow = (iw - kw) // sw + 1
    assert l.shape == (1, ow, 5, kw, 2)
    for w in range(ow):
        np.testing.assert_array_equal(l[0, w], xn[0, :, sw * w : sw * w + kw, :])


def test_dtype_bfloat16_close():
    """The kernel also lowers in bf16 (TPU-native dtype) within bf16 tol."""
    x = rand(1, (1, 8, 8, 2)).astype(jnp.bfloat16)
    k = rand(2, (3, 3, 2, 4)).astype(jnp.bfloat16)
    got = mec.mec_conv(x, k, (1, 1)).astype(jnp.float32)
    want = ref.conv2d_ref(
        x.astype(jnp.float32), k.astype(jnp.float32), (1, 1)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.1, atol=0.15)
